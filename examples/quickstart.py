"""Quickstart: train a small LM end-to-end with the framework's public
API — config registry, Model, Trainer (sharded, checkpointed, resumable).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.common.config import TrainConfig
from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.train.loop import Trainer, lm_batch_iterator


def main():
    # 1. pick an architecture from the registry (reduced config: this
    #    container; the same ModelConfig at full size drives the
    #    multi-pod dry-run)
    cfg = get_smoke_config("gemma2-2b")
    print(f"arch={cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={cfg.pattern}")

    # 2. trainer with checkpointing + auto-resume
    tc = TrainConfig(steps=120, learning_rate=2e-3, warmup_steps=10,
                     checkpoint_every=50, log_every=20,
                     checkpoint_dir="/tmp/repro_quickstart")
    model = Model(cfg)
    trainer = Trainer(model, tc, mesh=make_host_mesh())

    # 3. train on a synthetic Markov stream (loss should fall fast)
    res = trainer.run(lm_batch_iterator(cfg, batch=8, seq=128))
    print(f"loss: {res.losses[0]:.3f} -> {res.final_loss:.3f} "
          f"in {res.wall_s:.1f}s"
          + (f" (resumed from step {res.resumed_from})"
             if res.resumed_from else ""))
    assert res.final_loss < res.losses[0], "did not learn"
    print("quickstart OK")


if __name__ == "__main__":
    main()
