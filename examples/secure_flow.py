"""Service-enhanced RDMA flow (paper §5 end to end): the sender encrypts
on its TX path, the receiver decrypts on-path and runs ML-DPI on the
parallel path; the traffic sniffer (paper §4.7) captures the ciphertext
wire traffic into a PCAP you can open in Wireshark.

  PYTHONPATH=src python examples/secure_flow.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network
from repro.core.services import AesService, DpiService, ServiceChain
from repro.core.sniffer import TrafficSniffer
from repro.data.dpi_dataset import make_dataset, payload_with_embedded_malware
from repro.kernels.dpi_mlp import train_dpi_params

KEY = np.arange(16, dtype=np.uint8)


def main():
    # train the DPI model (paper: CSV/PNG/TXT vs executables)
    x, y = make_dataset(2048, seed=0)
    dpi_params = train_dpi_params(x, y, steps=200)

    rng = np.random.default_rng(0)
    benign = payload_with_embedded_malware(65536, 0.0, rng)  # text/CSV/PNG
    evil = payload_with_embedded_malware(65536, 0.2, rng)    # 20% malware

    net = Network(2, LinkConfig(loss_prob=0.02, latency_ticks=3, seed=1))
    sniffer = TrafficSniffer(capture_payload=True)
    # DPI must inspect the *decrypted* stream -> parallel_after placement
    recv_chain = ServiceChain(
        on_path=[AesService(key=KEY, decrypt=True)],
        parallel_after=[DpiService(params=dpi_params)])
    a = RdmaNode(0, net, sniffer=sniffer)
    b = RdmaNode(1, net, services=recv_chain)
    qpn_a, _, _ = a.init_rdma(1 << 18, b)

    enc = AesService(key=KEY)
    for name, data in (("benign", benign), ("malicious", evil)):
        ct = np.asarray(enc(jnp.asarray(data.reshape(-1, 4096)),
                            jnp.asarray(np.full(len(data) // 4096, 4096,
                                                np.int32))))
        flagged_before = b.stats.dpi_flagged
        a.rdma_write(qpn_a, ct.reshape(-1))
        run_network([a, b], max_ticks=50_000)
        got = b._qp_buffer[1][1][:len(data)]
        ok = (got == data).all()
        flags = b.stats.dpi_flagged - flagged_before
        print(f"[secure] {name:10s} delivered={ok} "
              f"dpi_flagged_packets={flags}/{len(data)//4096}")
        assert ok
    assert b.stats.dpi_flagged > 0, "DPI missed the malicious flow"

    n = sniffer.write_pcap("/tmp/balboa_flow.pcap")
    print(f"[secure] wrote {n} packets to /tmp/balboa_flow.pcap "
          f"(RoCE v2 BTH frames; wire payloads are AES ciphertext)")
    print("secure_flow OK")


if __name__ == "__main__":
    main()
