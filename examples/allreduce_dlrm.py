"""Data-parallel DLRM gradient exchange over BALBOA collectives — the
ML-fabric story end to end: W workers each train on their own shard of
the paper's §8 recommendation workload, and every optimizer step
exchanges gradients with an **allreduce that actually rides the RDMA
transport** (batched RX engine, retransmission, flow control), with the
in-fabric reduction offload folding the gradient chunks at the switch.

Verified against single-process training on the concatenated batch:
the distributed gradients match the oracle fold bit-for-bit, and the
resulting model matches data-parallel math to float tolerance.

  PYTHONPATH=src python examples/allreduce_dlrm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.dlrm import smoke_config
from repro.core.collectives import allreduce_oracle, make_ring_group
from repro.data import synthetic as syn
from repro.models.dlrm import DLRM

WORLD = 4
RECORDS_PER_WORKER = 64
STEPS = 8
LR = 0.05


def worker_batch(cfg, shard_idx):
    """Preprocessed features + labels for one worker's shard (the
    on-datapath preprocessing is exercised by examples/dlrm_ingest.py;
    here the collective is the star)."""
    raw = syn.dlrm_shard(shard_idx, RECORDS_PER_WORKER,
                         cfg.n_dense, cfg.n_sparse)
    dense = np.log1p(np.maximum(raw[:, :cfg.n_dense], 0)).astype(np.float32)
    sparse = (raw[:, cfg.n_dense:] % cfg.modulus).astype(np.int32)
    labels = syn.dlrm_labels(raw, cfg.n_dense, cfg.modulus)
    return {"dense": jnp.asarray(dense), "sparse": jnp.asarray(sparse),
            "label": jnp.asarray(labels)}


def main():
    cfg = smoke_config()
    model = DLRM(cfg)
    params = model.init_params(jax.random.key(0))
    flat0, unravel = ravel_pytree(params)
    n_grad = flat0.size
    print(f"[allreduce-dlrm] {WORLD} workers, {n_grad} gradient elements "
          f"({n_grad * 4 / 1024:.0f} KB) per exchange")

    grad_fn = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    loss_fn = jax.jit(lambda p, b: model.loss(p, b)[0])

    group = make_ring_group(WORLD, max_bytes=n_grad * 4 + WORLD * 4,
                            offload=True)
    batches = [worker_batch(cfg, r) for r in range(WORLD)]

    # the single-process oracle trains on the same per-worker batches,
    # averaging gradients with the canonical fold the fabric computes
    params_oracle = params

    t0 = time.time()
    losses = []
    for step in range(STEPS):
        # every worker computes gradients on its own shard...
        flats = [np.asarray(ravel_pytree(grad_fn(params, b))[0])
                 for b in batches]
        # ...and exchanges them through the fabric (offloaded allreduce)
        summed = group.allreduce(flats)
        want = allreduce_oracle(flats)
        for r in range(WORLD):
            assert (summed[r].view(np.uint8) == want.view(np.uint8)).all(), \
                f"step {step}: rank {r} gradient exchange not bit-identical"
        avg = jnp.asarray(summed[0]) / WORLD
        params = jax.tree.map(lambda p, g: p - LR * g, params, unravel(avg))

        params_oracle = jax.tree.map(
            lambda p, g: p - LR * g, params_oracle,
            unravel(jnp.asarray(want) / WORLD))

        mean_loss = float(np.mean([loss_fn(params, b) for b in batches]))
        losses.append(mean_loss)
        print(f"[allreduce-dlrm] step {step}: loss {mean_loss:.4f} "
              f"(exchange: {group.stats.ticks} fabric ticks total)")

    # distributed == oracle-fold training, bit-for-bit parameter match
    flat_a = np.asarray(ravel_pytree(params)[0])
    flat_b = np.asarray(ravel_pytree(params_oracle)[0])
    np.testing.assert_array_equal(flat_a, flat_b)
    assert losses[-1] < losses[0], "loss did not decrease"

    red = group.service.reducer
    dt = time.time() - t0
    print(f"[allreduce-dlrm] {STEPS} steps in {dt:.1f}s; loss "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}; switch folded "
          f"{red.bytes_reduced / 1024:.0f} KB across {red.reduced_forwarded} "
          f"fragments ({red.absorbed} contributions absorbed in-fabric); "
          f"params bit-identical to the oracle fold")
    print("allreduce_dlrm OK")


if __name__ == "__main__":
    main()
