"""Batched serving example: prefill a batch of prompts, decode with the
KV-cache runtime (ring caches on sliding-window layers, recurrent states
on SSM layers), greedy sampling.

  PYTHONPATH=src python examples/serve.py
"""
from repro.configs import get_smoke_config
from repro.launch.serve import serve_batch
from repro.models.model import Model


def main():
    for arch in ("gemma2-2b", "xlstm-125m", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        tokens, t_p, t_d = serve_batch(cfg, model, batch_size=4,
                                       prompt_len=32, gen=16)
        print(f"[serve] {arch:18s} prefill {t_p*1e3:7.1f}ms  "
              f"decode {t_d*1e3:7.1f}ms  "
              f"sample={tokens[0][:6].tolist()}")
    print("serve OK")


if __name__ == "__main__":
    main()
