"""The paper's §8 use case, end to end: DLRM online training where every
batch streams from disaggregated storage over BALBOA RDMA, is
preprocessed ON THE DATAPATH (Neg2Zero -> Log, Modulus), and lands
directly in device memory — the CPU never touches a feature byte.

  PYTHONPATH=src python examples/dlrm_ingest.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm import smoke_config
from repro.core.ingest import BalboaIngest, IngestConfig
from repro.core.services import PreprocService, ServiceChain
from repro.data import synthetic as syn
from repro.models.dlrm import DLRM


def main():
    cfg = smoke_config()
    rec_w = cfg.n_dense + cfg.n_sparse
    recs_per_pkt = (4096 // 4) // rec_w
    n_rec = recs_per_pkt * 8          # 8 packets per shard

    # --- storage shards: RAW records (negative dense, unbounded sparse)
    def shard_fn(i):
        return syn.encode_dlrm_shard(
            syn.dlrm_shard(i, n_rec, cfg.n_dense, cfg.n_sparse))

    # --- the on-datapath service: the paper's preprocessing pipeline
    # NOTE the shard header (3 int32 words) rides in front; the service
    # rewrites whole records, so we align shards to record boundaries by
    # padding the header to one full record (see encode/decode).
    chain = ServiceChain(on_path=[PreprocService(
        n_dense=cfg.n_dense, n_sparse=cfg.n_sparse, modulus=cfg.modulus)])

    # The stream is fragmented at MTU boundaries; the on-path service
    # frames records per packet, so the storage layout is RECORD-ALIGNED
    # to the MTU (26 records + pad per 4 KB packet) — on the FPGA this
    # alignment is what the FIRST/MIDDLE/LAST stream reassembly gives the
    # offload for free.
    n_pkts = 8
    pad_w = (4096 // 4) - recs_per_pkt * rec_w

    def shard_records_only(i):
        raw = syn.dlrm_shard(i, n_rec, cfg.n_dense, cfg.n_sparse)
        buf = np.zeros((n_pkts, 4096 // 4), np.int32)
        for p in range(n_pkts):
            chunk = raw[p * recs_per_pkt:(p + 1) * recs_per_pkt]
            buf[p, :recs_per_pkt * rec_w] = chunk.reshape(-1)
        return buf.reshape(-1).view(np.uint8)

    def decode_fn(raw):
        words = np.frombuffer(raw.tobytes(), np.int32).reshape(
            n_pkts, 4096 // 4)
        recs = np.concatenate([
            words[p, :recs_per_pkt * rec_w].reshape(recs_per_pkt, rec_w)
            for p in range(n_pkts)])
        dense = recs[:, :cfg.n_dense].copy().view(np.float32)
        sparse = recs[:, cfg.n_dense:]
        return {"dense": dense, "sparse": sparse}

    ing = BalboaIngest(
        IngestConfig(batch_bytes=8 * 4096, n_storage_nodes=2),
        chain, shard_records_only, decode_fn)

    model = DLRM(cfg)
    params = model.init_params(jax.random.key(0))

    @jax.jit
    def train_step(p, batch):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
        return p, l, m["acc"]

    t0 = time.time()
    losses = []
    for i, dev_batch in enumerate(ing.batches(30)):
        raw = syn.dlrm_shard(i, n_rec, cfg.n_dense, cfg.n_sparse)
        labels = syn.dlrm_labels(raw, cfg.n_dense, cfg.modulus)
        batch = {"dense": jnp.asarray(dev_batch["dense"]),
                 "sparse": jnp.asarray(dev_batch["sparse"]),
                 "label": jnp.asarray(labels)}
        # sanity: on-path preprocessing matches the reference
        want = np.log1p(np.maximum(raw[:, :cfg.n_dense], 0))
        np.testing.assert_allclose(np.asarray(batch["dense"]), want,
                                   rtol=1e-5)
        for _ in range(5):         # a few optimizer steps per shard
            params, loss, acc = train_step(params, batch)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"[dlrm] shard {i}: loss {float(loss):.4f} "
                  f"acc {float(acc):.3f}")
    dt = time.time() - t0
    print(f"[dlrm] 30 shards ({30*n_rec} records) in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"CPU never touched a feature byte (service chain: "
          f"{chain.describe()})")
    assert losses[-1] < losses[0]
    print("dlrm_ingest OK")


if __name__ == "__main__":
    main()
