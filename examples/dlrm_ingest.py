"""The paper's §8 use case, end to end: DLRM online training where every
batch STREAMS from disaggregated storage over BALBOA RDMA — striped
across all replicas on concurrent QPs, preprocessed tile-by-tile ON THE
DATAPATH the moment bytes are acknowledged (Neg2Zero -> Log, Modulus),
and landed directly in pre-sharded device buffers.  The CPU never
touches a feature byte: ``decode_fn`` is poisoned to prove it.

  PYTHONPATH=src python examples/dlrm_ingest.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm import smoke_config
from repro.core.ingest import (BalboaIngest, IngestConfig,
                               make_dlrm_tile_decoder)
from repro.data import synthetic as syn
from repro.models.dlrm import DLRM


def main():
    cfg = smoke_config()
    rec_w = cfg.n_dense + cfg.n_sparse
    recs_per_pkt = (4096 // 4) // rec_w
    n_pkts = 8                        # packets per shard
    n_rec = recs_per_pkt * n_pkts

    # --- storage shards: RAW records (negative dense, unbounded sparse)
    # in the record-aligned packet layout the stripes preserve
    def shard_fn(i):
        return syn.encode_dlrm_packets(
            syn.dlrm_shard(i, n_rec, cfg.n_dense, cfg.n_sparse))

    def poisoned_decode(raw):
        raise AssertionError("host decode touched payload bytes")

    # Streaming ingest: 2 replicas x 2 QPs, 2-packet fragment tiles.
    # Preprocessing runs per tile (the fused Pallas kernel) as each
    # tile's bytes are acknowledged — process-as-it-arrives.
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * 4096, n_storage_nodes=2,
                     qps_per_node=2, tile_pkts=2,
                     link_bw_pkts_per_tick=1),
        None, shard_fn, decode_fn=poisoned_decode,
        tile_to_batch=make_dlrm_tile_decoder(cfg.n_dense, cfg.n_sparse,
                                             cfg.modulus))

    model = DLRM(cfg)
    params = model.init_params(jax.random.key(0))

    @jax.jit
    def train_step(p, batch):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
        return p, l, m["acc"]

    t0 = time.time()
    losses, goodputs, overlaps = [], [], []
    for i, (dev_batch, rep) in enumerate(ing.stream_batches(30)):
        goodputs.append(rep.goodput_bytes_per_tick)
        overlaps.append(rep.overlap_efficiency)
        # labels are control-plane metadata (derived from the synthetic
        # rule), not payload bytes
        raw = syn.dlrm_shard(i, n_rec, cfg.n_dense, cfg.n_sparse)
        labels = syn.dlrm_labels(raw, cfg.n_dense, cfg.modulus)
        batch = {"dense": dev_batch["dense"],
                 "sparse": dev_batch["sparse"],
                 "label": jnp.asarray(labels)}
        # sanity: tile-granular on-arrival preprocessing == reference
        want = np.log1p(np.maximum(raw[:, :cfg.n_dense], 0))
        np.testing.assert_allclose(np.asarray(batch["dense"]), want,
                                   rtol=1e-5)
        for _ in range(5):         # a few optimizer steps per shard
            params, loss, acc = train_step(params, batch)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"[dlrm] shard {i}: loss {float(loss):.4f} "
                  f"acc {float(acc):.3f} "
                  f"goodput {rep.goodput_bytes_per_tick:.0f} B/tick "
                  f"overlap {rep.overlap_efficiency:.2f}")
    dt = time.time() - t0
    print(f"[dlrm] 30 shards ({30*n_rec} records) in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"mean goodput {np.mean(goodputs):.0f} B/tick, "
          f"mean overlap {np.mean(overlaps):.2f}; "
          f"host payload bytes copied: {ing.host_payload_bytes}")
    assert losses[-1] < losses[0]
    assert ing.host_payload_bytes == 0
    print("dlrm_ingest OK")


if __name__ == "__main__":
    main()
