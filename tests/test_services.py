"""Service-chain tests (paper §5): on-path AES transform, parallel-path
DPI decisions, DLRM preprocessing, and chain composition — plus the
end-to-end property that an encrypt-side + decrypt-side pair of BALBOA
nodes is transparent to the application."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.services import (AesService, CrcService, DpiService,
                                 PreprocService, ServiceChain)
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network
from repro.data.dpi_dataset import make_dataset, payload_with_embedded_malware
from repro.kernels.dpi_mlp import train_dpi_params

KEY = np.arange(16, dtype=np.uint8)


def test_aes_service_roundtrip():
    enc = AesService(key=KEY)
    dec = AesService(key=KEY, decrypt=True)
    pay = np.random.default_rng(0).integers(0, 256, (8, 4096), dtype=np.uint8)
    plen = np.full(8, 4096, np.int32)
    ct = np.asarray(enc(jnp.asarray(pay), jnp.asarray(plen)))
    assert not (ct == pay).all()
    pt = np.asarray(dec(jnp.asarray(ct), jnp.asarray(plen)))
    np.testing.assert_array_equal(pt, pay)


def test_preproc_service_transforms_records():
    svc = PreprocService(n_dense=13, n_sparse=26, modulus=1000)
    rec_words = 39
    n_rec = 4096 // 4 // rec_words
    recs = np.random.default_rng(1).integers(
        -50, 10**6, (2, n_rec * rec_words), dtype=np.int32)
    pay = np.zeros((2, 4096), np.uint8)
    pay[:, :n_rec * rec_words * 4] = recs.view(np.uint8)
    out = np.asarray(svc(jnp.asarray(pay), jnp.asarray([4096, 4096],
                                                       np.int32)))
    out_words = out[:, :n_rec * rec_words * 4].view(np.int32).reshape(
        2, n_rec, rec_words)
    want_dense = np.log1p(np.maximum(
        recs.reshape(2, n_rec, rec_words)[:, :, :13], 0).astype(np.float32))
    np.testing.assert_allclose(out_words[:, :, :13].view(np.float32),
                               want_dense, rtol=1e-6)
    np.testing.assert_array_equal(
        out_words[:, :, 13:], recs.reshape(2, n_rec, rec_words)[:, :, 13:]
        % 1000)


@pytest.fixture(scope="module")
def dpi_params():
    x, y = make_dataset(2048, seed=0)
    return train_dpi_params(x, y, steps=250)


def test_dpi_service_flags_malware(dpi_params):
    svc = DpiService(params=dpi_params)
    rng = np.random.default_rng(2)
    mal = np.stack([payload_with_embedded_malware(4096, 1.0, rng)
                    for _ in range(16)])
    ben = np.stack([payload_with_embedded_malware(4096, 0.0, rng)
                    for _ in range(16)])
    plen = np.full(16, 4096, np.int32)
    f_mal = np.asarray(svc(jnp.asarray(mal), jnp.asarray(plen)))
    f_ben = np.asarray(svc(jnp.asarray(ben), jnp.asarray(plen)))
    assert f_mal.mean() > 0.9, f"missed malware: {f_mal.mean()}"
    assert f_ben.mean() < 0.2, f"false positives: {f_ben.mean()}"


def test_service_chain_order_and_flags(dpi_params):
    """Parallel-path services see the pre-transform stream; on-path
    services compose in order."""
    enc = AesService(key=KEY)
    dpi = DpiService(params=dpi_params)
    chain = ServiceChain(on_path=[enc], parallel=[dpi])
    rng = np.random.default_rng(3)
    pay = np.stack([payload_with_embedded_malware(4096, 1.0, rng)
                    for _ in range(4)])
    plen = np.full(4, 4096, np.int32)
    out, flags = chain.process(jnp.asarray(pay), jnp.asarray(plen))
    # DPI inspected the *plaintext* copy -> flags fire even though the
    # on-path output is ciphertext
    assert np.asarray(flags).all()
    assert not (np.asarray(out) == pay).all()


def test_e2e_encrypted_rdma_flow(dpi_params):
    """Sender encrypts on its TX service chain; receiver decrypts on RX:
    the application sees plaintext, the wire sees ciphertext."""
    rng = np.random.default_rng(4)
    data = rng.integers(0, 256, 65536, dtype=np.uint8)
    net = Network(2, LinkConfig(latency_ticks=2, seed=5))
    # receiver runs decrypt on-path + DPI parallel-path
    recv_chain = ServiceChain(on_path=[AesService(key=KEY, decrypt=True)],
                              parallel=[DpiService(params=dpi_params)])
    a = RdmaNode(0, net)
    b = RdmaNode(1, net, services=recv_chain)
    qpn_a, _, _ = a.init_rdma(1 << 18, b)
    # encrypt before send (TX-side on-path service)
    enc = AesService(key=KEY)
    ct = np.asarray(enc(jnp.asarray(data.reshape(16, 4096)),
                        jnp.asarray(np.full(16, 4096, np.int32))))
    a.rdma_write(qpn_a, ct.reshape(-1))
    run_network([a, b], max_ticks=20_000)
    np.testing.assert_array_equal(b._qp_buffer[1][1][:len(data)], data)


def test_service_chain_flag_bit_layout():
    """Decision-flag bits have named positions exposed on the chain
    (pre-transform taps first, then post-transform taps) — consumers
    address flags by name, never by inspector insertion order."""
    from repro.core.services import ParallelPathService

    class _Always(ParallelPathService):
        def __init__(self, name):
            self.name = name

        def __call__(self, payload, plen):
            return jnp.ones(payload.shape[0], jnp.int32)

    class _Never(ParallelPathService):
        def __init__(self, name):
            self.name = name

        def __call__(self, payload, plen):
            return jnp.zeros(payload.shape[0], jnp.int32)

    chain = ServiceChain(parallel=[_Always("icrc"), _Never("rate-limit")],
                         parallel_after=[_Always("ml-dpi")])
    assert chain.flag_bits == {"icrc": 0, "rate-limit": 1, "ml-dpi": 2}
    pay = np.zeros((3, 256), np.uint8)
    _, flags = chain.process(jnp.asarray(pay),
                             jnp.asarray(np.full(3, 256, np.int32)))
    flags = np.asarray(flags)
    assert ((flags >> chain.flag_bits["icrc"]) & 1).all()
    assert not ((flags >> chain.flag_bits["rate-limit"]) & 1).any()
    assert ((flags >> chain.flag_bits["ml-dpi"]) & 1).all()
    # duplicate names get disambiguated, never silently merged
    dup = ServiceChain(parallel=[_Always("icrc"), _Never("icrc")])
    assert sorted(dup.flag_bits.values()) == [0, 1]
    # the SAME instance tapping both placements gets two distinct bits
    tap = _Always("ml-dpi")
    both = ServiceChain(parallel=[tap], parallel_after=[tap])
    assert sorted(both.flag_bits.values()) == [0, 1]
    _, f2 = both.process(jnp.asarray(pay),
                         jnp.asarray(np.full(3, 256, np.int32)))
    assert (np.asarray(f2) == 0b11).all()
    # the 32-bit host-directed command bounds the inspector count
    with pytest.raises(ValueError):
        ServiceChain(parallel=[_Never(f"i{i}") for i in range(33)])


def test_crc_service_flags_corruption():
    svc = CrcService()
    pay = np.random.default_rng(6).integers(0, 256, (4, 512), dtype=np.uint8)
    flags = np.asarray(svc(jnp.asarray(pay),
                           jnp.asarray(np.full(4, 512, np.int32))))
    assert flags.shape == (4,)          # (integrity values, smoke only)
