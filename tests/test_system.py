"""End-to-end system tests: the BALBOA ingest path feeding real training
(the paper's §8 flow), fault tolerance (crash -> checkpoint resume;
storage straggler -> replica failover), and checkpoint/sharding units."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.core.ingest import BalboaIngest, IngestConfig
from repro.core.services import PreprocService, ServiceChain
from repro.data import synthetic as syn
from repro.models.dlrm import DLRM
from repro.models.model import Model
from repro.parallel import sharding as sh
from repro.train.loop import Trainer, lm_batch_iterator


# ---------------------------------------------------------------------------
# Ingest: storage -> RDMA -> services -> device
# ---------------------------------------------------------------------------

def test_ingest_lm_shards_roundtrip():
    cfg = get_smoke_config("granite-3-2b")
    shard_fn = lambda i: syn.encode_lm_shard(
        syn.lm_shard(i, 4, 32, cfg.vocab))
    ing = BalboaIngest(IngestConfig(batch_bytes=1 << 16), None,
                       shard_fn, syn.decode_lm_shard)
    got = ing.fetch_shard(3)
    want = syn.lm_shard(3, 4, 32, cfg.vocab)
    np.testing.assert_array_equal(np.asarray(got["tokens"]), want["tokens"])
    np.testing.assert_array_equal(np.asarray(got["targets"]), want["targets"])


def test_ingest_straggler_failover():
    """First storage node never answers (dead peer): the QP timeout
    trips and the replica serves the shard."""
    cfg = get_smoke_config("granite-3-2b")
    shard_fn = lambda i: syn.encode_lm_shard(
        syn.lm_shard(i, 2, 16, cfg.vocab))
    ing = BalboaIngest(
        IngestConfig(batch_bytes=1 << 14, n_storage_nodes=2,
                     straggler_timeout_ticks=300), None,
        shard_fn, syn.decode_lm_shard)
    # kill node for shard 0's primary: drop all its outbound packets
    primary = ing.storage[0].node
    for (src, dst), link in ing.net.links.items():
        if src == primary.node_id:
            link.cfg.loss_prob = 1.0
    got = ing.fetch_shard(0)
    want = syn.lm_shard(0, 2, 16, cfg.vocab)
    np.testing.assert_array_equal(np.asarray(got["tokens"]), want["tokens"])
    assert ing.refetches >= 1


def test_ingest_failover_after_retry_exhaustion():
    """Killed storage node, deterministic: the trainer's retry budget
    exhausts (QP error) within the straggler window, the replica serves
    the shard via reestablish_qp, and the error state is cleared."""
    cfg = get_smoke_config("granite-3-2b")
    shard_fn = lambda i: syn.encode_lm_shard(
        syn.lm_shard(i, 2, 16, cfg.vocab))
    ing = BalboaIngest(
        IngestConfig(batch_bytes=1 << 14, n_storage_nodes=2,
                     straggler_timeout_ticks=400), None,
        shard_fn, syn.decode_lm_shard)
    # tight retry budget so exhaustion fits inside one straggler window
    ing.trainer.retx.MAX_RETRIES = 2
    ing.trainer.retx.timeout = 20
    primary = ing.storage[0].node
    for (src, dst), link in ing.net.links.items():
        if src == primary.node_id:          # kill ALL outbound traffic
            link.cfg.loss_prob = 1.0
    got = ing.fetch_shard(0)
    want = syn.lm_shard(0, 2, 16, cfg.vocab)
    np.testing.assert_array_equal(np.asarray(got["tokens"]), want["tokens"])
    assert ing.refetches >= 1
    # the dead QP genuinely exhausted its budget and was surfaced...
    assert ing.trainer.retx.exhausted
    qpn_dead = ing.trainer.retx.exhausted[0][0]
    # ...then cleared by the reestablish during failover
    assert not ing.trainer.qp_error(qpn_dead)
    assert ing.trainer.retx.outstanding(qpn_dead) == 0


def test_ingest_preprocessed_dlrm_stream():
    """Paper §8 end to end: raw records stream through the on-path
    preprocessing service and arrive device-ready."""
    n_dense, n_sparse, modulus = 13, 26, 1000
    mtu_records = (4096 // 4) // (n_dense + n_sparse)
    n_rec = mtu_records * 4       # 4 full packets
    shard_fn = lambda i: syn.encode_dlrm_shard(
        syn.dlrm_shard(i, n_rec, n_dense, n_sparse))
    # NOTE: header words travel in packet 0 — the service must not mangle
    # them; PreprocService only rewrites whole records, and we align the
    # payload so the 3-word header occupies the first record slot.
    raw = syn.dlrm_shard(7, n_rec, n_dense, n_sparse)
    svc = PreprocService(n_dense=n_dense, n_sparse=n_sparse, modulus=modulus)
    chain = ServiceChain(on_path=[svc])
    # feed the records directly (unit of the ingest transform)
    pay = np.zeros((4, 4096), np.uint8)
    rec_bytes = (n_dense + n_sparse) * 4
    per_pkt = mtu_records
    for p in range(4):
        chunk = raw[p * per_pkt:(p + 1) * per_pkt]
        pay[p, :per_pkt * rec_bytes] = chunk.view(np.uint8).reshape(-1)
    out, _ = chain.process(jnp.asarray(pay),
                           jnp.asarray(np.full(4, 4096, np.int32)))
    out = np.asarray(out)
    recs = np.concatenate([
        out[p, :per_pkt * rec_bytes].view(np.int32).reshape(per_pkt, -1)
        for p in range(4)])
    dense = recs[:, :n_dense].view(np.float32)
    np.testing.assert_allclose(
        dense, np.log1p(np.maximum(raw[:, :n_dense], 0)), rtol=1e-6)
    np.testing.assert_array_equal(recs[:, n_dense:],
                                  raw[:, n_dense:] % modulus)


# ---------------------------------------------------------------------------
# Fault tolerance: crash -> resume
# ---------------------------------------------------------------------------

def test_crash_resume_training(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    tc = TrainConfig(steps=10, checkpoint_every=4, learning_rate=1e-3,
                     checkpoint_dir=str(tmp_path / "ck"), log_every=100)
    m = Model(cfg)
    with pytest.raises(RuntimeError, match="injected failure"):
        Trainer(m, tc).run(lm_batch_iterator(cfg, 4, 32), crash_at=6)
    res = Trainer(m, tc).run(lm_batch_iterator(cfg, 4, 32))
    assert res.resumed_from == 4
    assert res.steps_run == 6          # 4..9
    assert np.isfinite(res.final_loss)


def test_training_reduces_loss(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    tc = TrainConfig(steps=30, checkpoint_every=1000, learning_rate=3e-3,
                     warmup_steps=5, checkpoint_dir=str(tmp_path / "ck2"),
                     log_every=1000)
    m = Model(cfg)
    res = Trainer(m, tc).run(lm_batch_iterator(cfg, 8, 64))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.1, f"no learning: {first} -> {last}"


# ---------------------------------------------------------------------------
# DLRM end to end (paper §8 model behind preprocessed features)
# ---------------------------------------------------------------------------

def test_dlrm_trains():
    from repro.configs.dlrm import smoke_config
    cfg = smoke_config()
    model = DLRM(cfg)
    params = model.init_params(jax.random.key(0))
    raw = syn.dlrm_shard(0, 512, cfg.n_dense, cfg.n_sparse)
    labels = syn.dlrm_labels(raw, cfg.n_dense, cfg.modulus)
    dense = np.log1p(np.maximum(raw[:, :cfg.n_dense], 0)).astype(np.float32)
    sparse = (raw[:, cfg.n_dense:] % cfg.modulus).astype(np.int32)
    batch = {"dense": jnp.asarray(dense), "sparse": jnp.asarray(sparse),
             "label": jnp.asarray(labels)}

    # heavy-ball momentum: plain constant-step GD oscillates around the
    # optimum on this full-batch problem instead of settling
    vel = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(p, v):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        v = jax.tree.map(lambda vv, gg: 0.9 * vv + gg, v, g)
        p = jax.tree.map(lambda a, b: a - 0.005 * b, p, v)
        return p, v, l, m["acc"]

    accs = []
    for _ in range(200):
        params, vel, loss, acc = step(params, vel)
        accs.append(float(acc))
    assert accs[-1] > 0.8, f"DLRM failed to learn: acc={accs[-1]}"


# ---------------------------------------------------------------------------
# Checkpoint + sharding units
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path / "c"))
    state = {"a": jnp.arange(10, dtype=jnp.float32),
             "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(7, state, blocking=True)
    step, got = ck.restore(state)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path / "c"), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.asarray(s)}, blocking=True)
    assert ck.all_steps() == [3, 4]


def test_sharding_divisibility_fallback():
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = sh.make_rules("train")
    spec = sh.resolve_spec((8, 128), ("batch", "d_ff"), mesh, rules, "t")
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # indivisible dim falls back to replication (and is logged)
    sh.clear_fallback_log()
    mesh2 = jax.make_mesh((1,), ("model",))
    spec2 = sh.resolve_spec((7,), ("d_ff",), mesh2,
                            {"d_ff": ((("model",)), None)}, "t2")
    # 7 % 1 == 0 so it shards; now force indivisible with a fake size
    assert spec2 is not None


def test_mtp_loss_present():
    cfg = get_smoke_config("deepseek-v3-671b")
    assert cfg.mtp
    m = Model(cfg)
    params = m.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    loss, metrics = m.loss(params, {"tokens": toks,
                                    "targets": jnp.roll(toks, -1, 1)})
    assert "mtp" in metrics and np.isfinite(float(metrics["mtp"]))
