"""Transport-layer tests: exactly-once in-order delivery under loss and
reorder, flow-control / credit invariants (hypothesis property tests),
and RX pipeline PSN semantics."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.flow_control import (AckClockedFlowControl, CreditManager,
                                     FlowControlConfig)
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network
from repro.core.retransmit import RetransmissionBuffer


# ---------------------------------------------------------------------------
# End-to-end reliability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss,reorder", [(0.0, 0.0), (0.02, 0.0),
                                          (0.1, 0.05), (0.3, 0.1)])
def test_write_exactly_once_under_loss(loss, reorder):
    net = Network(2, LinkConfig(loss_prob=loss, reorder_prob=reorder,
                                latency_ticks=3, seed=11))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, _ = a.init_rdma(1 << 19, b)
    data = np.random.default_rng(5).integers(0, 256, 200_000, dtype=np.uint8)
    a.rdma_write(qpn_a, data)
    run_network([a, b], max_ticks=60_000)
    recv = b._qp_buffer[1][1][:len(data)]
    np.testing.assert_array_equal(recv, data)
    # exactly-once: each of the 49 fragments DMA'd exactly once
    assert b.stats.accepted == pk.read_resp_npkts(len(data))
    if loss == 0:
        assert a.stats.retransmissions == 0


def test_read_under_loss():
    net = Network(2, LinkConfig(loss_prob=0.08, latency_ticks=2, seed=3))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, buf_a = a.init_rdma(1 << 19, b)
    data = np.random.default_rng(6).integers(0, 256, 120_000, dtype=np.uint8)
    buf_a[:len(data)] = data
    b.rdma_read(1, len(data))
    run_network([a, b], max_ticks=60_000)
    np.testing.assert_array_equal(b._qp_buffer[1][1][:len(data)], data)


def test_multi_qp_isolation():
    """Streams on different QPs never corrupt each other."""
    net = Network(2, LinkConfig(loss_prob=0.05, latency_ticks=2, seed=9))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qps = [a.init_rdma(1 << 17, b)[0] for _ in range(4)]
    datas = [np.random.default_rng(i).integers(0, 256, 50_000 + i * 1000,
                                               dtype=np.uint8)
             for i in range(4)]
    for q, d in zip(qps, datas):
        a.rdma_write(q, d)
    run_network([a, b], max_ticks=60_000)
    for i, (q, d) in enumerate(zip(qps, datas)):
        qpn_b = i + 1          # both managers allocate QPNs in lockstep
        recv = b._qp_buffer[qpn_b][1][:len(d)]
        np.testing.assert_array_equal(recv, d, err_msg=f"qp {q}")


# ---------------------------------------------------------------------------
# Flow control invariants (paper §4.4)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["req", "ack"]),
                          st.integers(1, 8)), max_size=200),
       st.integers(1, 32))
def test_flow_control_invariants(events, window):
    fc = AckClockedFlowControl(2, FlowControlConfig(window))
    submitted = passed = 0
    for kind, n in events:
        n = min(n, window)           # a request larger than W can't pass
        if kind == "req":
            submitted += 1
            passed += len(fc.request(0, n))
        else:
            passed += len(fc.ack(0, n))
        # INVARIANT: outstanding never exceeds the window
        assert fc.outstanding[0] <= window
        assert fc.budget[0] >= 0
    # INVARIANT: flow control delays but never drops
    assert passed + fc.queue_depth(0) == submitted


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["consume", "replenish"]),
                          st.integers(1, 4)), max_size=200),
       st.integers(1, 16))
def test_credit_invariants(events, cap):
    cm = CreditManager(1, cap, cap)
    for kind, n in events:
        if kind == "consume":
            cm.try_consume(0, n)
        else:
            cm.replenish(0, n)
        assert 0 <= cm.credits[0] <= cap
    assert cm.accepted <= cm.granted


def test_credit_drop_recovers_via_retransmit():
    """Packets dropped for lack of credits are recovered (paper §4.3)."""
    net = Network(2, LinkConfig(latency_ticks=1, seed=2))
    a = RdmaNode(0, net)
    b = RdmaNode(1, net, rx_credits=2)     # tiny downstream capacity
    qpn_a, _, _ = a.init_rdma(1 << 19, b)
    data = np.random.default_rng(8).integers(0, 256, 150_000, dtype=np.uint8)
    a.rdma_write(qpn_a, data)
    run_network([a, b], max_ticks=120_000)
    np.testing.assert_array_equal(b._qp_buffer[1][1][:len(data)], data)
    assert b.stats.credit_dropped > 0      # pressure actually happened
    assert a.stats.retransmissions > 0


# ---------------------------------------------------------------------------
# Retransmission buffer
# ---------------------------------------------------------------------------

def test_retransmit_timeout_and_ack_release():
    rb = RetransmissionBuffer(timeout_ticks=10)
    pkts = pk.fragment_message(1, 0, 0, 1, np.zeros(10000, np.uint8))
    for p in pkts:
        rb.hold(1, p, now=0)
    assert rb.outstanding(1) == len(pkts)
    out = rb.tick(now=11)
    assert len(out) == len(pkts)            # all timed out
    rb.ack(1, pkts[0].psn)                  # cumulative ack first
    assert rb.outstanding(1) == len(pkts) - 1
    rb.ack(1, pkts[-1].psn)
    assert rb.outstanding(1) == 0
    assert rb.tick(now=1000) == []


def test_retransmit_exponential_backoff_then_exhaustion():
    """A never-acked slot is retried with doubling deadlines until the
    retry budget runs out, then evicted and reported — not retried
    forever."""
    rb = RetransmissionBuffer(timeout_ticks=10)
    rb.MAX_RETRIES = 4
    p = pk.fragment_message(1, 0, 0, 1, np.zeros(10, np.uint8))[0]
    rb.hold(1, p, now=0)
    resend_times = []
    for t in range(1, 2000):
        if rb.tick(t):
            resend_times.append(t)
        if not rb.outstanding(1):
            break
    assert resend_times == [10, 30, 70, 150]     # gaps 10, 20, 40, 80
    gaps = np.diff([0] + resend_times)
    assert all(g2 == 2 * g1 for g1, g2 in zip(gaps, gaps[1:]))
    assert rb.exhausted == [(1, 0)]              # fatal, surfaced
    assert rb.outstanding(1) == 0                # slot evicted
    assert rb.tick(3000) == []                   # and it stays quiet


@pytest.mark.parametrize("cc", ["ack_clocked", "dcqcn"])
def test_retry_exhaustion_surfaces_qp_error(cc):
    """Dead peer: the node ends up with a QP error instead of an
    infinite retransmit loop, and reestablish_qp clears it — including
    any rate-paced resends still staged from the old PSN space."""
    net = Network(2, LinkConfig(loss_prob=1.0, latency_ticks=1, seed=5))
    a = RdmaNode(0, net, congestion_control=cc)
    b = RdmaNode(1, net)
    qpn, _, _ = a.init_rdma(1 << 14, b)
    a.retx.MAX_RETRIES = 3
    a.retx.timeout = 8
    a.rdma_write(qpn, np.zeros(3 * pk.MTU, np.uint8))
    ticks = run_network([a, b], max_ticks=5000)
    assert ticks < 5000                          # did NOT loop forever
    assert a.qp_error(qpn)
    assert a.retx.exhausted and a.retx.exhausted[0][0] == qpn
    assert a.retx.outstanding(qpn) == 0          # slots evicted
    a.reestablish_qp(qpn)
    assert not a.qp_error(qpn)
    assert int(a.qp.tables.npsn[qpn]) == 0       # fresh PSN space
    assert qpn not in a._retx_staged             # no stale PSNs leak


# ---------------------------------------------------------------------------
# RX pipeline PSN semantics (jax scan FSM)
# ---------------------------------------------------------------------------

def _mk_batch(specs):
    pkts = []
    for (opcode, qpn, psn, plen) in specs:
        pkts.append(pk.Packet(opcode=opcode, qpn=qpn, psn=psn,
                              payload=np.zeros(plen, np.uint8),
                              vaddr=0, dma_len=plen))
    b = pk.batch_from_packets(pkts, mtu=256)
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_rx_pipeline_accept_dup_ooo():
    t = pipe.make_rx_tables(4, initial_credits=16)
    batch = _mk_batch([
        (pk.WRITE_ONLY, 1, 0, 100),    # in-seq -> accept
        (pk.WRITE_ONLY, 1, 0, 100),    # duplicate -> dup
        (pk.WRITE_ONLY, 1, 2, 100),    # gap -> out-of-order NAK
        (pk.WRITE_ONLY, 1, 1, 100),    # next expected -> accept
    ])
    t, res = pipe.rx_pipeline(t, batch)
    assert list(np.asarray(res.accept)) == [True, False, False, True]
    assert list(np.asarray(res.dup)) == [False, True, False, False]
    assert list(np.asarray(res.ooo)) == [False, False, True, False]
    assert int(t.epsn[1]) == 2


def test_rx_pipeline_multi_packet_message_addresses():
    t = pipe.make_rx_tables(4, initial_credits=16)
    pkts = pk.fragment_message(2, 0, vaddr=1000, rkey=1,
                               data=np.zeros(600, np.uint8), mtu=256)
    b = pk.batch_from_packets(pkts, mtu=256)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    t, res = pipe.rx_pipeline(t, b)
    assert np.asarray(res.accept).all()
    np.testing.assert_array_equal(np.asarray(res.dma_addr),
                                  [1000, 1256, 1512])
    assert int(t.msn[2]) == 1              # one completed message


def test_rx_pipeline_credit_drop():
    t = pipe.make_rx_tables(4, initial_credits=1)
    batch = _mk_batch([(pk.WRITE_ONLY, 1, 0, 10), (pk.WRITE_ONLY, 1, 1, 10)])
    t, res = pipe.rx_pipeline(t, batch)
    assert list(np.asarray(res.accept)) == [True, False]
    assert list(np.asarray(res.dropped_credit)) == [False, True]
    # ePSN did NOT advance for the dropped packet -> retransmit lands in-seq
    assert int(t.epsn[1]) == 1


# ---------------------------------------------------------------------------
# Remote-access protection (rkey validation)
# ---------------------------------------------------------------------------

def test_write_wrong_rkey_naks_protection_error():
    """A WRITE presenting a bogus rkey is NAKed fatally: nothing is
    DMA'd, the responder counts a protection error, and the requester's
    QP goes to the error state instead of retrying forever."""
    net = Network(2, LinkConfig(latency_ticks=2, seed=1))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, _ = a.init_rdma(1 << 12, b)
    a._remote_rkey[qpn_a] = 0xBAD            # corrupt the exchanged key
    data = np.arange(256, dtype=np.uint8)
    a.rdma_write(qpn_a, data)
    run_network([a, b], max_ticks=5_000)
    assert b.stats.prot_errors >= 1
    assert b.stats.accepted == 0
    assert (b._qp_buffer[1][1] == 0).all()   # buffer untouched
    assert a.stats.nak_prot_rx >= 1
    assert a.qp_error(qpn_a)
    # recovery path: re-exchange (fix the key) + reestablish both ends
    a._remote_rkey[qpn_a] = b._local_rkey[1]
    a.reestablish_qp(qpn_a)
    b.reestablish_qp(1)
    a.rdma_write(qpn_a, data)
    run_network([a, b], max_ticks=5_000)
    assert not a.qp_error(qpn_a)
    np.testing.assert_array_equal(b._qp_buffer[1][1][:256], data)


def test_read_wrong_rkey_not_served():
    """_on_read_request validates the wire rkey against the registered
    buffer instead of trusting it: a bogus key gets NAK_PROT and zero
    response packets."""
    net = Network(2, LinkConfig(latency_ticks=2, seed=2))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, _ = a.init_rdma(1 << 12, b)
    secret = np.random.default_rng(3).integers(0, 256, 512, dtype=np.uint8)
    b._qp_buffer[1][1][:512] = secret        # responder-side data
    a._remote_rkey[qpn_a] = 0xBAD
    a.rdma_read(qpn_a, 512)
    run_network([a, b], max_ticks=5_000)
    assert b.stats.prot_errors == 1
    assert a.stats.nak_prot_rx >= 1
    assert a.qp_error(qpn_a)
    assert a.check_completed(qpn_a) == 0     # no response stream
    assert (a._qp_buffer[qpn_a][1][:512] == 0).all()


def test_rx_pipeline_rkey_mismatch_flags_not_accepts():
    """In-graph protection check (both engines share it): a RETH packet
    with the wrong rkey raises rkey_err, leaves ePSN alone, and does
    not consume a credit."""
    t = pipe.make_rx_tables(4, initial_credits=16)
    t = t._replace(rkey=t.rkey.at[1].set(77))
    pkts = [pk.Packet(opcode=pk.WRITE_ONLY, qpn=1, psn=0, vaddr=0,
                      rkey=42, dma_len=8, ack_req=True,
                      payload=np.arange(8, dtype=np.uint8))]
    b = pk.batch_from_packets(pkts)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    t, res = pipe.rx_pipeline(t, b)
    assert bool(res.rkey_err[0]) and not bool(res.accept[0])
    assert int(t.epsn[1]) == 0
    assert int(t.credits[1]) == 16
    # the right key sails through
    pkts[0].rkey = 77
    b2 = pk.batch_from_packets(pkts)
    t, res = pipe.rx_pipeline(t, {k: jnp.asarray(v) for k, v in b2.items()})
    assert bool(res.accept[0]) and not bool(res.rkey_err[0])
