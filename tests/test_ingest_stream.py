"""Streaming ingest (paper §8: process-as-it-arrives RDMA -> device).

What must hold:
  * streamed preproc output is BIT-identical to the one-shot oracle
    (same records through one `preproc_ref` call), with and without
    striping, loss, short final tiles, and the on-path service variant;
  * a replica dying MID-stream costs a re-fetch of only ITS stripes
    (per-stripe failover), and the payload still comes out identical;
  * transport ticks and tile kernel hand-offs interleave (the overlap
    the paper's deep pipeline buys);
  * payload bytes never pass through a host-side decode copy — enforced
    by poisoning ``decode_fn`` and counting ``host_payload_bytes``;
  * remote QPNs come from the connection table, so storage nodes can
    hold several QPs (striping's prerequisite);
  * the RX credit ledger is visible per stripe.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.ingest import (BalboaIngest, IngestConfig,
                               make_dlrm_tile_decoder)
from repro.core.services import PreprocService, ServiceChain
from repro.data import synthetic as syn
from repro.kernels.preproc import preproc_ref

N_DENSE, N_SPARSE, MOD = 13, 26, 1000
REC_W = N_DENSE + N_SPARSE
RPP = (4096 // 4) // REC_W            # records per packet
MTU = 4096


def _shard_fn(n_pkts):
    return lambda i: syn.encode_dlrm_packets(
        syn.dlrm_shard(i, RPP * n_pkts, N_DENSE, N_SPARSE))


def _oracle(index, n_pkts):
    """One-shot path: all records through one preproc call."""
    raw = syn.dlrm_shard(index, RPP * n_pkts, N_DENSE, N_SPARSE)
    return np.asarray(preproc_ref(jnp.asarray(raw), N_DENSE, MOD))


def _assert_matches_oracle(batch, index, n_pkts):
    want = _oracle(index, n_pkts)
    got_dense = np.asarray(batch["dense"])[:RPP * n_pkts]
    got_sparse = np.asarray(batch["sparse"])[:RPP * n_pkts]
    # bit-level: compare the dense f32 through its exact bit pattern
    np.testing.assert_array_equal(got_dense.view(np.int32),
                                  want[:, :N_DENSE])
    np.testing.assert_array_equal(got_sparse, want[:, N_DENSE:])


def _poison(raw):
    raise AssertionError("decode_fn touched payload bytes on the host")


def test_streamed_bit_identity_vs_oneshot_oracle():
    n_pkts = 16
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2),
        None, _shard_fn(n_pkts), decode_fn=_poison,
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    batch, rep = ing.fetch_shard_streaming(3)
    _assert_matches_oracle(batch, 3, n_pkts)
    assert rep.tiles == n_pkts // 2
    assert rep.refetches == 0
    # the poisoned decode_fn never fired and no payload byte crossed a
    # host-side decode copy
    assert ing.host_payload_bytes == 0


def test_streamed_bit_identity_with_onpath_service():
    """Same oracle, but preprocessing happens INSIDE the RX pipeline
    (on-path service); the tile decoder then only splits columns."""
    n_pkts = 8
    chain = ServiceChain(on_path=[PreprocService(
        n_dense=N_DENSE, n_sparse=N_SPARSE, modulus=MOD)])
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2),
        chain, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, None))
    batch, _ = ing.fetch_shard_streaming(3)
    _assert_matches_oracle(batch, 3, n_pkts)


def test_streamed_bit_identity_short_final_tile_and_odd_striping():
    """7 packets over 2 stripes (4+3) with 2-packet tiles: the final
    tile of each stripe is short; identity must survive the padding."""
    n_pkts = 7
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    batch, rep = ing.fetch_shard_streaming(11)
    _assert_matches_oracle(batch, 11, n_pkts)
    assert [s.n_pkts for s in rep.stripes] == [4, 3]


def test_streamed_bit_identity_under_loss():
    """Retransmission underneath the watermark: lossy links must only
    delay tiles, never corrupt or reorder them."""
    n_pkts = 12
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2, loss_prob=0.05),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    batch, _ = ing.fetch_shard_streaming(4)
    _assert_matches_oracle(batch, 4, n_pkts)


def test_midstream_replica_death_refetches_only_its_stripes():
    n_pkts = 16
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     link_bw_pkts_per_tick=1, tile_pkts=2,
                     stall_ticks=150),
        None, _shard_fn(n_pkts), decode_fn=_poison,
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    # tight retry budget: the dead QP exhausts inside the stall window
    ing.trainer.retx.MAX_RETRIES = 2
    ing.trainer.retx.timeout = 20
    dead = ing.storage[0].node

    def kill(t):                      # node 0 dies MID-stream
        if t == 3:
            for (src, dst), link in ing.net.links.items():
                if src == dead.node_id:
                    link.cfg.loss_prob = 1.0

    # drive via the low-level API so the fault hook can fire, collecting
    # tiles exactly like fetch_shard_streaming does
    tiles = {}

    def consume(stripe, tidx, dev, nv):
        tiles[(stripe.sid, tidx)] = (np.asarray(dev), nv, stripe.pkt_start)

    rep = ing.stream_shard(7, consume, on_tick=kill)
    # ONLY the dead node's stripe re-fetched, on the surviving replica
    by_sid = {s.sid: s for s in rep.stripes}
    assert rep.refetches == 1
    assert by_sid[0].refetches == 1 and by_sid[0].attempts == (0, 1)
    assert by_sid[1].refetches == 0 and by_sid[1].attempts == (1,)
    # payload identical to the shard despite the death
    out = np.zeros(n_pkts * MTU, np.uint8)
    for (sid, tidx), (arr, nv, pkt_start) in tiles.items():
        lo = (pkt_start + tidx * 2) * MTU
        out[lo:lo + nv * MTU] = arr.reshape(-1)[:nv * MTU]
    want = np.asarray(_shard_fn(n_pkts)(7))
    np.testing.assert_array_equal(out[:want.size], want)
    assert ing.host_payload_bytes == 0


def test_transient_outage_then_reuse_no_stale_payload():
    """A TRANSIENT outage (peer alive, link lossy, then healed): after
    per-stripe failover, re-using the recovered QP for the next shard
    must deliver THAT shard's bytes.  A one-sided reestablish would let
    the peer's stale retransmit ring replay the old transfer with the
    PSNs a zero-reset trainer expects — silent stale payload.  The
    two-sided fresh-epoch reestablish makes the replays un-acceptable."""
    n_pkts = 16
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     link_bw_pkts_per_tick=1, tile_pkts=2,
                     stall_ticks=150),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    ing.trainer.retx.MAX_RETRIES = 2
    ing.trainer.retx.timeout = 20
    flaky = ing.storage[0].node

    def outage(t):                    # node 0 mute from tick 3...
        if t == 3:
            for (src, dst), link in ing.net.links.items():
                if src == flaky.node_id:
                    link.cfg.loss_prob = 1.0

    _, rep0 = _run_stream_with_hook(ing, 0, outage)
    assert rep0.refetches >= 1
    # ...link heals; the next shard goes over the SAME (recovered) QPs
    for link in ing.net.links.values():
        link.cfg.loss_prob = 0.0
    batch, rep1 = ing.fetch_shard_streaming(1)
    assert rep1.refetches == 0
    _assert_matches_oracle(batch, 1, n_pkts)


def _run_stream_with_hook(ing, index, on_tick):
    """fetch_shard_streaming with a fault-injection hook: same tile
    collection, driven through the low-level stream_shard API."""
    tiles = {}

    def consume(stripe, tidx, dev, nv):
        tiles[(stripe.sid, tidx)] = np.asarray(dev)

    rep = ing.stream_shard(index, consume, on_tick=on_tick)
    return tiles, rep


def test_midstream_failover_refetches_only_unconsumed_suffix():
    """Tiles consumed before the replica died are NOT re-transferred:
    the refetch READ resumes at the last emitted tile boundary."""
    n_pkts = 16
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     link_bw_pkts_per_tick=1, tile_pkts=2,
                     stall_ticks=150),
        None, _shard_fn(n_pkts), decode_fn=_poison,
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    ing.trainer.retx.MAX_RETRIES = 2
    ing.trainer.retx.timeout = 20
    dead = ing.storage[0].node

    def kill(t):                      # die after stripe 0 emitted tiles
        if t == 12:                   # 2 of its 4 tiles are out by now
            for (src, dst), link in ing.net.links.items():
                if src == dead.node_id:
                    link.cfg.loss_prob = 1.0
                    link._heap.clear()    # node death loses in-flight
                                          # frames too, not just new ones

    tiles = {}

    def consume(stripe, tidx, dev, nv):
        tiles[(stripe.sid, tidx)] = (np.asarray(dev), nv, stripe.pkt_start)

    rep = ing.stream_shard(9, consume, on_tick=kill)
    s0 = {s.sid: s for s in rep.stripes}[0]
    assert s0.refetches == 1
    assert s0.resume > 0, "refetch did not resume mid-stripe"
    assert s0.resume % (2 * MTU) == 0      # tile-aligned
    # payload still identical
    out = np.zeros(n_pkts * MTU, np.uint8)
    for (sid, tidx), (arr, nv, pkt_start) in tiles.items():
        lo = (pkt_start + tidx * 2) * MTU
        out[lo:lo + nv * MTU] = arr.reshape(-1)[:nv * MTU]
    want = np.asarray(_shard_fn(n_pkts)(9))
    np.testing.assert_array_equal(out[:want.size], want)


def test_all_replicas_dead_raises():
    n_pkts = 4
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2, stall_ticks=100),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    ing.trainer.retx.MAX_RETRIES = 2
    ing.trainer.retx.timeout = 20
    for (src, dst), link in ing.net.links.items():
        if src != 0:                  # every storage node mute
            link.cfg.loss_prob = 1.0
    with pytest.raises(RuntimeError, match="all replicas failed"):
        ing.fetch_shard_streaming(0)


def test_transport_and_kernel_calls_interleave():
    """The point of streaming: tile hand-offs happen WHILE later bytes
    are still on the wire, not after the transfer."""
    n_pkts = 32
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=4,
                     link_bw_pkts_per_tick=1, tile_pkts=2),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    _, rep = ing.fetch_shard_streaming(0)
    tile_ticks = [e[1] for e in rep.events if e[0] == "tile"]
    done_ticks = [e[1] for e in rep.events if e[0] == "done"]
    # tiles were consumed strictly before the transport finished...
    assert min(tile_ticks) < rep.transport_done_tick
    assert rep.tiles_overlapped > 0
    assert rep.overlap_efficiency > 0.5
    # ...and the interleave is genuine: tile events are spread across
    # the transfer, with transport completions still to come after the
    # first tiles were already processed
    assert min(tile_ticks) < min(done_ticks) <= max(done_ticks)
    assert rep.goodput_bytes_per_tick > 0


def test_multi_qp_per_node_remote_qpn_derivation():
    """A storage node holding >1 QP: remote QPNs must come from the
    connection table per QP (the old max(dict-keys) guess collapses
    every stripe onto the last-created QP)."""
    n_pkts = 8
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     qps_per_node=2, tile_pkts=2),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    # 4 QPs over 2 nodes; each node's two remote QPNs are distinct and
    # exactly what the trainer's connection table says
    by_node = {}
    for qp in ing.qps:
        assert qp.qpn_r == ing.trainer.remote_qpn(qp.qpn_l)
        by_node.setdefault(qp.node, set()).add(qp.qpn_r)
    assert all(len(v) == 2 for v in by_node.values())
    # and the striped fetch over all 4 QPs still reproduces the oracle
    batch, rep = ing.fetch_shard_streaming(2)
    _assert_matches_oracle(batch, 2, n_pkts)
    assert len(rep.stripes) == 4


def test_per_stripe_credit_ledger_exposed():
    n_pkts = 12
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    _, rep = ing.fetch_shard_streaming(1)
    ledgers = rep.ledgers
    assert set(ledgers) == {s.sid for s in rep.stripes}
    for s in rep.stripes:
        led = ledgers[s.sid]
        # every packet of the stripe consumed (and returned) one credit
        assert led.accepted == s.n_pkts
        assert led.dropped == 0
        assert 0 <= led.credits <= led.max_credits
    # per-QP ledgers reconcile with the aggregate counters
    agg = sum(ing.trainer.credits.accepted_per_qp)
    assert agg == ing.trainer.credits.accepted


def test_legacy_sync_path_counts_host_copies():
    """The store-and-forward baseline still works — and its host decode
    copy is exactly what the counter (and the streaming plane) tracks."""
    n_pkts = 4
    raw_bytes = n_pkts * MTU
    ing = BalboaIngest(
        IngestConfig(batch_bytes=raw_bytes, n_storage_nodes=2),
        None, _shard_fn(n_pkts),
        decode_fn=lambda raw: {"raw": np.frombuffer(raw.tobytes(),
                                                    np.uint8)})
    got = ing.fetch_shard(6)
    np.testing.assert_array_equal(np.asarray(got["raw"]),
                                  _shard_fn(n_pkts)(6))
    assert ing.host_payload_bytes == raw_bytes


def test_streamed_bit_identity_over_clos_spray():
    """Reorder-hardening: the streaming plane over a leaf-spine fabric
    in per-packet spray mode (asymmetric spine delays => out-of-order
    READ-response arrivals) with selective-repeat RX.  The contiguous
    completion watermark the tile consumer polls must stay sound under
    out-of-order DMA, so the streamed output is still bit-identical to
    the one-shot oracle."""
    n_pkts = 16
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=2,
                     tile_pkts=2, topology="clos",
                     rx_mode="selective_repeat", path_select="spray"),
        None, _shard_fn(n_pkts), decode_fn=_poison,
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    batch, rep = ing.fetch_shard_streaming(3)
    _assert_matches_oracle(batch, 3, n_pkts)
    assert rep.tiles == n_pkts // 2
    assert rep.refetches == 0
    assert ing.host_payload_bytes == 0
    # the fabric genuinely sprayed across both spine planes
    assert all(n > 0 for n in ing.net.spine_pkts)
