"""Fixture: unsorted dict iteration reaching the wire must be flagged."""


class Node:
    def __init__(self):
        self.flows = {}

    def send(self, dst, pkt):
        pass

    def flush(self):
        for dst, pkt in self.flows.items():   # unsorted -> wire
            self.send(dst, pkt)

    def flush_sorted(self):
        # negative case: sorted() iteration is insertion-history-free
        for dst in sorted(self.flows):
            self.send(dst, self.flows[dst])

    def tally(self):
        # negative case: unsorted iteration NOT reaching the wire
        total = 0
        for _, pkt in self.flows.items():
            total += len(pkt)
        return total
