"""Fixture: suppression comments hide violations from the report."""
# balint: disable=mutable-default
import time


def stamp():
    return time.time()  # balint: disable=wall-clock


def accumulate(x, acc=[]):        # hidden by the file-level disable
    acc.append(x)
    return acc
