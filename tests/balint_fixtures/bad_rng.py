"""Fixture: unseeded / global RNG the determinism pass must flag."""
import numpy as np
from numpy.random import default_rng


def entropy():
    xs = np.random.randint(0, 10, 4)      # global RNG
    np.random.shuffle(xs)                 # global RNG
    rng = default_rng()                   # unseeded stream
    return rng.integers(0, 10)
