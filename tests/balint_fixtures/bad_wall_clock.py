"""Fixture: wall-clock reads the determinism pass must flag."""
import time
from datetime import datetime


def stamp():
    t = time.time()
    p = time.perf_counter()
    m = time.monotonic()
    d = datetime.now()
    return t, p, m, d
