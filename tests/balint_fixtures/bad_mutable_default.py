"""Fixture: mutable default arguments the pass must flag."""


def accumulate(x, acc=[]):
    acc.append(x)
    return acc


def index(k, v, table={}, *, tags=set()):
    table[k] = v
    tags.add(k)
    return table
