"""Fixture: the negative cases — none of these may be flagged."""
import numpy as np


def seeded(seed):
    rng = np.random.default_rng(seed)       # seeded stream: fine
    return rng.integers(0, 10)


def ordered_send(node, flows):
    for dst in sorted(flows):               # sorted wire iteration: fine
        node.send(dst, flows[dst])
    for dst in sorted(set(flows)):          # sorted() consumes the set
        node.send(dst, flows[dst])


def immutable_defaults(x, y=(), z=None):
    if z is None:
        z = []
    return x, y, z


def tick_clock(now):
    return now + 1                          # the only clock: integer ticks
