"""Fixture: set iteration (hash-randomized order) the pass must flag."""


def drain(queues):
    order = []
    for q in {1, 2, 3}:                   # set literal
        order.append(q)
    for q in set(queues):                 # set() call
        order.append(q)
    doubled = [q * 2 for q in set(queues)]  # comprehension over a set
    return order, doubled
