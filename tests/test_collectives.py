"""Collective subsystem tests: ring and offloaded schedules bit-
identical to the jnp oracle across world sizes, odd chunk sizes, lossy
fabrics (drops + retransmit) and reruns (determinism); tree broadcast;
the switch reducer's transport bookkeeping."""
import numpy as np
import pytest

from repro.core import packet as pk
from repro.core.collectives import (AllreduceService, CollectiveGroup,
                                    allreduce_oracle, make_ring_group)
from repro.core.netsim import FabricConfig, SwitchedFabric

LOSSY = FabricConfig(port_bandwidth=4, port_delay=2, queue_capacity=48,
                     loss_prob=0.05, seed=21)


def _tensors(world, n_elems, seed=7, dtype=np.float32):
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.floating):
        return [rng.standard_normal(n_elems).astype(dtype)
                for _ in range(world)]
    return [rng.integers(-10_000, 10_000, n_elems, dtype=dtype)
            for _ in range(world)]


def _bit_identical(a: np.ndarray, b: np.ndarray) -> bool:
    return (np.ascontiguousarray(a).view(np.uint8)
            == np.ascontiguousarray(b).view(np.uint8)).all()


# ---------------------------------------------------------------------------
# Allreduce == oracle, all modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("world", [2, 4, 8])
@pytest.mark.parametrize("offload", [False, True])
def test_allreduce_bit_identical_to_oracle(world, offload):
    xs = _tensors(world, 1000 + world)       # odd: not divisible by world
    g = make_ring_group(world, 1 << 16, offload=offload)
    out = g.allreduce(xs)
    want = allreduce_oracle(xs)
    for r in range(world):
        assert _bit_identical(out[r], want), f"rank {r}"
    # plain-sum sanity: canonical fold == jnp.sum to float tolerance
    np.testing.assert_allclose(want, np.sum(xs, axis=0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_elems", [1, 5, 997])
def test_allreduce_odd_chunk_sizes(n_elems):
    """Tensors smaller than / not divisible by the world size exercise
    padded chunks end to end."""
    xs = _tensors(4, n_elems, seed=n_elems)
    for offload in (False, True):
        g = make_ring_group(4, 1 << 14, offload=offload)
        out = g.allreduce(xs)
        want = allreduce_oracle(xs)
        assert all(_bit_identical(out[r], want) for r in range(4))


def test_allreduce_int32_matches_plain_sum():
    xs = _tensors(3, 777, dtype=np.int32)
    g = make_ring_group(3, 1 << 14, dtype="int32")
    out = g.allreduce(xs)
    want = np.sum(xs, axis=0, dtype=np.int32)
    assert all((o == want).all() for o in out)


@pytest.mark.parametrize("offload", [False, True])
def test_allreduce_lossy_fabric(offload):
    """Drops + retransmission must not change a single bit."""
    xs = _tensors(4, 20_000, seed=3)
    g = make_ring_group(4, 1 << 18, fabric_cfg=LOSSY, offload=offload)
    out = g.allreduce(xs)
    want = allreduce_oracle(xs)
    assert sum(n.stats.retransmissions for n in g.nodes) > 0, \
        "lossy fabric produced no retransmissions — test is vacuous"
    assert all(_bit_identical(out[r], want) for r in range(4))


@pytest.mark.parametrize("offload", [False, True])
def test_allreduce_deterministic_across_runs(offload):
    """Two fresh groups on identically-seeded fabrics replay the same
    ticks and the same bits."""
    xs = _tensors(4, 5_000, seed=11)
    runs = []
    for _ in range(2):
        g = make_ring_group(4, 1 << 16, fabric_cfg=LOSSY, offload=offload)
        runs.append((g.allreduce(xs), g.stats.ticks))
    (out_a, ticks_a), (out_b, ticks_b) = runs
    assert ticks_a == ticks_b
    for r in range(4):
        assert _bit_identical(out_a[r], out_b[r])


def test_ring_and_offload_agree_bitwise():
    """The strongest form of the contract: the two schedules compute the
    same association, so their outputs agree bit-for-bit."""
    xs = _tensors(4, 9_999, seed=5)
    ring = make_ring_group(4, 1 << 16, offload=False).allreduce(xs)
    off = make_ring_group(4, 1 << 16, offload=True).allreduce(xs)
    assert all(_bit_identical(a, b) for a, b in zip(ring, off))


# ---------------------------------------------------------------------------
# Reduce-scatter / allgather / broadcast
# ---------------------------------------------------------------------------

def test_reduce_scatter_shards():
    xs = _tensors(4, 1002, seed=9)
    g = make_ring_group(4, 1 << 14)
    shards = g.reduce_scatter(xs)
    want = allreduce_oracle(xs)
    chunk = -(-1002 // 4)
    for r in range(4):
        lo, hi = r * chunk, min((r + 1) * chunk, 1002)
        assert _bit_identical(shards[r], want[lo:hi]), f"rank {r}"


def test_allgather_concatenates_in_rank_order():
    shards = _tensors(4, 251, seed=13)
    g = make_ring_group(4, 1 << 14)
    out = g.allgather(shards)
    want = np.concatenate(shards)
    assert all(_bit_identical(o, want) for o in out)


@pytest.mark.parametrize("world,root", [(2, 0), (4, 2), (5, 4), (8, 3)])
def test_broadcast_tree(world, root):
    rng = np.random.default_rng(root)
    x = rng.standard_normal((17, 9)).astype(np.float32)
    g = make_ring_group(world, 1 << 12)
    out = g.broadcast(x, root=root)
    assert len(out) == world
    assert all(_bit_identical(o, x) for o in out)


def test_broadcast_lossy():
    x = np.random.default_rng(1).standard_normal(16_384).astype(np.float32)
    g = make_ring_group(5, 1 << 17, fabric_cfg=FabricConfig(
        port_bandwidth=4, port_delay=2, queue_capacity=48,
        loss_prob=0.15, seed=21))
    out = g.broadcast(x, root=1)
    assert sum(n.stats.retransmissions for n in g.nodes) > 0
    assert all(_bit_identical(o, x) for o in out)


# ---------------------------------------------------------------------------
# The transport ribbon: collectives ride the verbs, the offload rides
# the switch
# ---------------------------------------------------------------------------

def test_offload_absorbs_at_the_hop():
    """In-fabric reduction: the owner ports see ONE reduced chunk
    instead of N-1, and the switch ACKs what it absorbs."""
    xs = _tensors(4, 40_000, seed=2)
    ring = make_ring_group(4, 1 << 18, offload=False)
    ring.allreduce(xs)
    off = make_ring_group(4, 1 << 18, offload=True)
    off.allreduce(xs)
    red = off.service.reducer
    assert red.absorbed > 0 and red.acks_synthesized > 0
    assert red.reduced_forwarded > 0
    assert red.in_flight == 0                # nothing left held
    # the reduce phase's data deliveries shrink: total payload packets
    # delivered by the fabric drop vs. the pure ring at equal settings
    ring_pkts = ring.net.total_delivered
    off_pkts = off.net.total_delivered
    assert off_pkts < ring_pkts, (off_pkts, ring_pkts)


def test_offload_survives_dcqcn_pacing():
    from repro.core.netsim import dcqcn_fabric_profile
    xs = _tensors(4, 30_000, seed=8)
    g = make_ring_group(4, 1 << 18, fabric_cfg=dcqcn_fabric_profile(),
                        congestion_control="dcqcn", offload=True)
    out = g.allreduce(xs)
    want = allreduce_oracle(xs)
    assert all(_bit_identical(out[r], want) for r in range(4))


def test_completion_polling_is_exercised():
    """Receivers account arriving sub-messages via check_completed —
    the collective layer verifies every transfer against
    expected_completions."""
    g = make_ring_group(2, 1 << 14)
    xs = _tensors(2, 512)
    g.allreduce(xs)
    # neighbor QPs saw completions on both sides
    assert g.nodes[0].check_completed(g._qpn[0][1]) > 0
    assert g.nodes[1].check_completed(g._qpn[1][0]) > 0


def test_chunk_packets_are_tagged_and_untagged_paths_coexist():
    """CHUNK tagging is per-write: untagged traffic on a reducer-armed
    fabric still forwards normally (the allgather phase shares QPs with
    carrier streams)."""
    g = make_ring_group(4, 1 << 14, offload=True)
    xs = _tensors(4, 512)
    out = g.allreduce(xs)          # reduce offloaded, allgather plain ring
    want = allreduce_oracle(xs)
    assert all(_bit_identical(out[r], want) for r in range(4))
    assert g.service.reducer.reduced_forwarded > 0


def test_reducer_requires_registration():
    """Tagged traffic without the control-plane QP map is a hard error
    (misconfiguration must not silently corrupt)."""
    fab = SwitchedFabric(2, FabricConfig())
    svc = AllreduceService(fab, dtype="float32")
    p = pk.Packet(opcode=pk.WRITE_ONLY, qpn=1, psn=0, src_ip=1,
                  coll_tag=7, coll_src=0, coll_nsrc=2, coll_frag=0,
                  ack_req=True, payload=np.zeros(8, np.uint8))
    with pytest.raises(RuntimeError, match="no QP registered"):
        svc.reducer.on_packet(0, p)


def test_fabric_rejects_second_reducer():
    """Silently replacing an attached reducer would strand the first
    group's tagged traffic on the wrong control plane."""
    fab = SwitchedFabric(2, FabricConfig())
    AllreduceService(fab, dtype="float32")
    with pytest.raises(RuntimeError, match="already has a reducer"):
        AllreduceService(fab, dtype="int32")


def test_group_validates_inputs():
    g = make_ring_group(2, 1 << 10)
    with pytest.raises(ValueError):
        g.allreduce([np.zeros(3, np.float32), np.zeros(4, np.float32)])
    with pytest.raises(ValueError):
        g.allreduce([np.zeros((1 << 12), np.float32)] * 2)  # > max_bytes
    with pytest.raises(ValueError):
        CollectiveGroup(g.nodes[:1], 1024)


def test_allreduce_over_clos_spray_selective_repeat():
    """Reorder-hardening: ring allreduce across a leaf-spine fabric in
    per-packet spray mode (asymmetric spine delays => genuinely
    out-of-order neighbor exchanges) with selective-repeat RX still
    reproduces the jnp oracle bit-for-bit — and without a single
    retransmission, because nothing was lost, only reordered."""
    from repro.core.netsim import ClosConfig
    xs = _tensors(4, 9_000, seed=9)
    cfg = ClosConfig(nodes_per_leaf=1, n_spines=2, port_bandwidth=4,
                     port_delay=1, queue_capacity=48, spine_delay=(1, 5),
                     seed=21, path_mode="spray")
    g = make_ring_group(4, 1 << 16, fabric_cfg=cfg,
                        rx_mode="selective_repeat", path_select="spray")
    out = g.allreduce(xs)
    want = allreduce_oracle(xs)
    assert all(_bit_identical(out[r], want) for r in range(4))
    fabric = g.nodes[0].net
    assert all(n > 0 for n in fabric.spine_pkts), \
        "spray never exercised one of the spine planes — test is vacuous"
    assert sum(n.stats.retransmissions for n in g.nodes) == 0
