"""Perf-regression gate tests: ``benchmarks/regress.py`` must pass on
the committed baselines and fail on synthetically degraded results —
the property the CI gating step relies on.
"""
import copy
import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from benchmarks import regress                       # noqa: E402

BASELINES = {
    "fig6": ROOT / "BENCH_fig6_multipath.json",
    "fig10": ROOT / "BENCH_fig10_dlrm.json",
    "fig11": ROOT / "BENCH_fig11_allreduce.json",
}


def _load(fig):
    with open(BASELINES[fig]) as f:
        return json.load(f)


def test_baselines_committed_and_extractable():
    for fig, path in BASELINES.items():
        assert path.exists(), f"missing committed baseline {path.name}"
        metrics = regress.EXTRACTORS[fig](_load(fig))
        assert metrics, f"{fig}: extractor found no metrics"
        for key, (val, direction) in metrics.items():
            assert direction in ("higher", "lower"), key
            assert isinstance(val, (int, float)), key
            # tick-based metrics only: no wall-clock leaks into the gate
            assert "wall" not in key and "_us" not in key, (
                f"{fig}:{key} looks wall-clock-based")


def test_regress_passes_on_identical(capsys):
    args = []
    for fig, path in BASELINES.items():
        args += ["--pair", fig, str(path), str(path)]
    assert regress.main(args) == 0
    assert "no perf regressions" in capsys.readouterr().out


def _degrade(doc):
    bad = copy.deepcopy(doc)
    for r in bad.get("incast_cc", []):
        r["goodput_B_per_tick"] *= 0.5
        r["retransmissions"] += 100
    for r in bad.get("multipath", []):
        r["goodput_B_per_tick"] *= 0.5
    return bad


def test_regress_fails_on_degraded(tmp_path, capsys):
    bad_path = tmp_path / "fig6_bad.json"
    bad_path.write_text(json.dumps(_degrade(_load("fig6"))))
    rc = regress.main(["--pair", "fig6", str(BASELINES["fig6"]),
                       str(bad_path)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "PERF REGRESSION" in out and "REGRESSED" in out


def test_regress_within_tolerance_passes(tmp_path):
    doc = _load("fig6")
    near = copy.deepcopy(doc)
    for r in near.get("incast_cc", []):
        r["goodput_B_per_tick"] *= 0.97        # 3% < 5% tolerance
    near_path = tmp_path / "fig6_near.json"
    near_path.write_text(json.dumps(near))
    assert regress.main(["--pair", "fig6", str(BASELINES["fig6"]),
                         str(near_path)]) == 0


def test_regress_flags_missing_metric(tmp_path):
    doc = _load("fig11")
    trimmed = copy.deepcopy(doc)
    trimmed["allreduce"] = trimmed["allreduce"][:-1]
    p = tmp_path / "fig11_trim.json"
    p.write_text(json.dumps(trimmed))
    assert regress.main(["--pair", "fig11", str(BASELINES["fig11"]),
                         str(p)]) == 1


def test_regress_flags_mode_mismatch(tmp_path):
    doc = _load("fig10")
    full = copy.deepcopy(doc)
    full["mode"] = "full"
    p = tmp_path / "fig10_full.json"
    p.write_text(json.dumps(full))
    assert regress.main(["--pair", "fig10", str(BASELINES["fig10"]),
                         str(p)]) == 1


def test_abs_slack_absorbs_tiny_counter_flaps(tmp_path):
    doc = _load("fig11")
    tweaked = copy.deepcopy(doc)
    for r in tweaked["allreduce"]:
        r["retransmissions"] += 1              # 0 -> 1: within abs slack
    p = tmp_path / "fig11_tweak.json"
    p.write_text(json.dumps(tweaked))
    assert regress.main(["--pair", "fig11", str(BASELINES["fig11"]),
                         str(p)]) == 0
