"""Executable documentation: the code snippets in README.md and docs/
must not rot.

* every fenced ```python block is extracted and EXECUTED (fresh
  namespace per block);
* every fenced ```bash block is parsed command by command, and each
  ``python <script>`` / ``python -m <module>`` the docs tell users to
  type must reference a file or module that actually exists.

Wired into CI twice: the tier-1 job runs this with the whole suite, and
the ``docs`` job runs it alone for fast docs-only signal.
"""
from __future__ import annotations

import importlib.util
import re
import shlex
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
DOCS = [REPO / "README.md",
        REPO / "docs" / "ARCHITECTURE.md",
        REPO / "docs" / "BENCHMARKS.md",
        REPO / "docs" / "BALINT.md"]

_FENCE = re.compile(r"^```(\w*)\s*$")


def _blocks(path: Path):
    """Yield (lang, first_line_no, text) for every tagged fenced block."""
    lang, start, buf = None, 0, []
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _FENCE.match(line)
        if m and lang is None:
            lang, start, buf = m.group(1) or "", i + 1, []
        elif line.strip() == "```" and lang is not None:
            if lang:
                yield lang, start, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def _collect(kind: str):
    out = []
    for doc in DOCS:
        for lang, line, text in _blocks(doc):
            if lang == kind:
                out.append(pytest.param(
                    doc, line, text,
                    id=f"{doc.relative_to(REPO)}:{line}"))
    return out


PY_BLOCKS = _collect("python")
BASH_BLOCKS = _collect("bash")


def test_docs_carry_snippets():
    """The extraction itself must keep finding snippets — an empty
    parametrization would silently stop guarding the docs."""
    assert len(PY_BLOCKS) >= 1
    assert len(BASH_BLOCKS) >= 2


@pytest.mark.parametrize("doc,line,text", PY_BLOCKS)
def test_python_snippets_execute(doc, line, text):
    code = compile(text, f"{doc.name}:{line}", "exec")
    exec(code, {"__name__": "__docsnippet__"})


def _check_python_cmd(argv, doc, line):
    if argv and argv[0] == "-m":
        mod = argv[1]
        if importlib.util.find_spec(mod.split(".")[0]) is not None \
                and "." not in mod:
            return                      # e.g. `python -m pytest`
        rel = Path(*mod.split("."))
        roots = [REPO, REPO / "src"]        # docs say PYTHONPATH=src
        assert any((r / rel.with_suffix(".py")).exists() or
                   (r / rel / "__main__.py").exists() for r in roots), \
            f"{doc.name}:{line}: `python -m {mod}` target missing"
    elif argv:
        script = argv[0]
        assert (REPO / script).exists(), \
            f"{doc.name}:{line}: `python {script}` does not exist"


def _logical_lines(text: str):
    """Join ``\\``-continued lines so multi-line commands parse whole."""
    pending = ""
    for raw in text.splitlines():
        raw = raw.strip()
        if raw.endswith("\\"):
            pending += raw[:-1] + " "
            continue
        yield pending + raw
        pending = ""
    if pending:
        yield pending.rstrip()


@pytest.mark.parametrize("doc,line,text", BASH_BLOCKS)
def test_bash_snippets_reference_real_targets(doc, line, text):
    for raw in _logical_lines(text):
        if not raw or raw.startswith("#"):
            continue
        toks = shlex.split(raw, comments=True)
        # drop ENV=VAL prefixes
        while toks and "=" in toks[0] and not toks[0].startswith("-"):
            toks.pop(0)
        if not toks or toks[0] != "python":
            continue                    # only python invocations checked
        args = [t for t in toks[1:] if not (t.startswith("-")
                                            and t not in ("-m",))]
        _check_python_cmd(args, doc, line)
