"""Multi-pod dry-run smoke: one real cell lowered + compiled in a fresh
subprocess (the 512-device XLA flag must be set before jax init, so this
cannot run in-process with the rest of the suite).  The full 80-cell
sweep is benchmarks/roofline.py."""
import json
import os
import subprocess
import sys

import pytest

_SNIPPET = r"""
import json, sys
from repro.launch.dryrun import run_cell
r = run_cell("gemma2-2b", "long_500k", False, verbose=False)
print("RESULT " + json.dumps(r))
"""


@pytest.mark.timeout(600)
def test_dryrun_single_cell_compiles():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run([sys.executable, "-c", _SNIPPET],
                          capture_output=True, text=True, timeout=580,
                          env=env, cwd=root)
    result = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            result = json.loads(line[len("RESULT "):])
    assert result is not None, proc.stderr[-800:]
    assert result["status"] == "ok", result
    assert result["chips"] == 256
    assert result["terms"]["memory_s"] > 0
    # long-context decode on a hybrid local/global arch: the KV cache is
    # sequence-sharded, so per-device argument bytes must be far below
    # the unsharded cache size
    assert result["memory"]["argument_bytes"] < 64 * 2**30
