"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle,
with shape/dtype sweeps (hypothesis) and authoritative external checks
(FIPS-197 vectors for AES, zlib for CRC32)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import (CRC_TABLE, expand_key, aes_decrypt_ref,
                               aes_encrypt_ref)
from repro.kernels.dpi_mlp import init_dpi_params, ternarize, train_dpi_params

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# AES
# ---------------------------------------------------------------------------

def test_aes_fips197_vector():
    key = np.arange(16, dtype=np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    rk = expand_key(key)
    ct = np.asarray(ops.aes_ecb(jnp.asarray(pt[None]), rk, impl="ref"))[0]
    assert ct.tobytes().hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"
    ct2 = np.asarray(ops.aes_ecb(jnp.asarray(pt[None]), rk, impl="pallas"))[0]
    assert (ct == ct2).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 2048), seed=st.integers(0, 2**31))
def test_aes_pallas_matches_ref_and_roundtrips(n, seed):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 256, (n, 16), dtype=np.uint8)
    rk = expand_key(rng.integers(0, 256, 16, dtype=np.uint8))
    e_p = np.asarray(ops.aes_ecb(jnp.asarray(blocks), rk, impl="pallas"))
    e_r = np.asarray(ops.aes_ecb(jnp.asarray(blocks), rk, impl="ref"))
    np.testing.assert_array_equal(e_p, e_r)
    d = np.asarray(ops.aes_ecb(jnp.asarray(e_p), rk, decrypt=True,
                               impl="pallas"))
    np.testing.assert_array_equal(d, blocks)


def test_aes_ecb_identical_blocks_leak():
    """ECB property the paper's service inherits: identical plaintext
    blocks -> identical ciphertext blocks (documented limitation)."""
    key = RNG.integers(0, 256, 16, dtype=np.uint8)
    rk = expand_key(key)
    blocks = np.tile(RNG.integers(0, 256, (1, 16), dtype=np.uint8), (4, 1))
    ct = np.asarray(ops.aes_ecb(jnp.asarray(blocks), rk))
    assert (ct == ct[0]).all()


# ---------------------------------------------------------------------------
# CRC32
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 64), mtu=st.sampled_from([64, 256, 512, 4096]),
       seed=st.integers(0, 2**31))
def test_crc32_matches_zlib(n, mtu, seed):
    rng = np.random.default_rng(seed)
    pay = rng.integers(0, 256, (n, mtu), dtype=np.uint8)
    plen = rng.integers(0, mtu + 1, n).astype(np.int32)
    for impl in ("pallas", "ref"):
        got = np.asarray(ops.crc32(jnp.asarray(pay), jnp.asarray(plen),
                                   impl=impl))
        want = np.array([zlib.crc32(pay[i, :plen[i]].tobytes()) & 0xFFFFFFFF
                         for i in range(n)], np.uint32)
        np.testing.assert_array_equal(got, want, err_msg=impl)


def test_crc32_detects_corruption():
    pay = RNG.integers(0, 256, (4, 512), dtype=np.uint8)
    plen = np.full(4, 512, np.int32)
    c1 = np.asarray(ops.crc32(jnp.asarray(pay), jnp.asarray(plen)))
    pay2 = pay.copy()
    pay2[2, 100] ^= 0x01          # single bit flip
    c2 = np.asarray(ops.crc32(jnp.asarray(pay2), jnp.asarray(plen)))
    assert c1[2] != c2[2] and (c1[[0, 1, 3]] == c2[[0, 1, 3]]).all()


# ---------------------------------------------------------------------------
# DPI MLP
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 16), mtu=st.sampled_from([64, 256, 4096]),
       seed=st.integers(0, 2**31))
def test_dpi_pallas_matches_ref(n, mtu, seed):
    rng = np.random.default_rng(seed)
    params = ternarize(init_dpi_params(jax.random.key(seed % 97)))
    pay = rng.integers(0, 256, (n, mtu), dtype=np.uint8)
    s_p = np.asarray(ops.dpi_scores(jnp.asarray(pay), params, impl="pallas"))
    s_r = np.asarray(ops.dpi_scores(jnp.asarray(pay), params, impl="ref"))
    np.testing.assert_allclose(s_p, s_r, rtol=1e-5, atol=1e-5)


def test_dpi_training_separates_classes():
    from repro.data.dpi_dataset import make_dataset
    x, y = make_dataset(1024, seed=1)
    params = train_dpi_params(x, y, steps=200)
    xt, yt = make_dataset(512, seed=2)
    scores = np.asarray(ops.dpi_scores(
        jnp.asarray(xt.reshape(len(xt), 64)), params, impl="ref"))[:, 0]
    acc = ((scores > 0) == (yt > 0.5)).mean()
    assert acc > 0.85, f"ternary DPI accuracy too low: {acc}"


# ---------------------------------------------------------------------------
# Fused decrypt+DPI chain (one-HBM-pass kernel vs two-pass oracle)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 40), mtu=st.sampled_from([256, 1024]),
       seed=st.integers(0, 2**31))
def test_fused_chain_matches_ref_odd_shapes(n, mtu, seed):
    """Equivalence across packet counts NOT divisible by BLOCK_N (the
    grid-padding path), multiple MTUs, and random keys: identical
    plaintext, allclose DPI scores."""
    from repro.kernels.fused_chain import (BLOCK_N, fused_decrypt_dpi_pallas,
                                           fused_decrypt_dpi_ref)
    rng = np.random.default_rng(seed)
    if n % BLOCK_N == 0:
        n += 1                              # force the padded-grid path
    pay = rng.integers(0, 256, (n, mtu), dtype=np.uint8)
    rk = expand_key(rng.integers(0, 256, 16, dtype=np.uint8))
    params = ternarize(init_dpi_params(jax.random.key(seed % 89)))
    p_f, s_f = fused_decrypt_dpi_pallas(jnp.asarray(pay), rk, params)
    p_r, s_r = fused_decrypt_dpi_ref(jnp.asarray(pay), rk, params)
    assert p_f.shape == (n, mtu) and s_f.shape == (n,)
    np.testing.assert_array_equal(np.asarray(p_f), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)


def test_fused_chain_decrypt_roundtrip():
    """The fused kernel's decrypt really is AES^-1: encrypt with the
    reference, fuse-decrypt, recover the plaintext bytes."""
    from repro.kernels.fused_chain import fused_decrypt_dpi_pallas
    rng = np.random.default_rng(3)
    plain = rng.integers(0, 256, (7, 256), dtype=np.uint8)
    key = rng.integers(0, 256, 16, dtype=np.uint8)
    rk = expand_key(key)
    ct = np.asarray(ops.aes_ecb(jnp.asarray(plain.reshape(-1, 16)), rk,
                                impl="ref")).reshape(7, 256)
    params = ternarize(init_dpi_params(jax.random.key(0)))
    p_f, _ = fused_decrypt_dpi_pallas(jnp.asarray(ct), rk, params)
    np.testing.assert_array_equal(np.asarray(p_f), plain)


def test_fused_chain_tile_entry_matches_oneshot():
    """Streaming tile entry: short tiles padded to the fixed shape must
    return rows bit-identical to the one-shot call over the same rows."""
    from repro.kernels.fused_chain import (fused_decrypt_dpi_pallas,
                                           fused_decrypt_dpi_tile)
    rng = np.random.default_rng(11)
    pay = rng.integers(0, 256, (13, 256), dtype=np.uint8)
    rk = expand_key(rng.integers(0, 256, 16, dtype=np.uint8))
    params = ternarize(init_dpi_params(jax.random.key(5)))
    p_all, s_all = fused_decrypt_dpi_pallas(jnp.asarray(pay), rk, params)
    for lo, hi in ((0, 8), (8, 13)):        # full tile + short final tile
        p_t, s_t = fused_decrypt_dpi_tile(jnp.asarray(pay[lo:hi]), rk,
                                          params, tile_pkts=8)
        np.testing.assert_array_equal(np.asarray(p_t),
                                      np.asarray(p_all)[lo:hi])
        np.testing.assert_array_equal(np.asarray(s_t),
                                      np.asarray(s_all)[lo:hi])
    with pytest.raises(ValueError, match="tile carries"):
        fused_decrypt_dpi_tile(jnp.asarray(pay), rk, params, tile_pkts=8)


# ---------------------------------------------------------------------------
# DLRM preprocessing
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 700), n_dense=st.integers(1, 16),
       n_sparse=st.integers(1, 30), modulus=st.sampled_from([7, 1000, 100000]),
       seed=st.integers(0, 2**31))
def test_preproc_pallas_matches_ref(m, n_dense, n_sparse, modulus, seed):
    rng = np.random.default_rng(seed)
    recs = rng.integers(-10**6, 2**30, (m, n_dense + n_sparse)
                        ).astype(np.int32)
    p = np.asarray(ops.preproc(jnp.asarray(recs), n_dense, modulus,
                               impl="pallas"))
    r = np.asarray(ops.preproc(jnp.asarray(recs), n_dense, modulus,
                               impl="ref"))
    np.testing.assert_array_equal(p, r)


def test_preproc_tile_entry_matches_oneshot():
    """Streamed tiles (including a short final tile) reproduce the
    one-shot kernel bit for bit — the ingest's bit-identity contract at
    the kernel layer."""
    from repro.kernels.preproc import preproc_tile
    rng = np.random.default_rng(7)
    recs = rng.integers(-10**6, 2**30, (77, 39)).astype(np.int32)
    want = np.asarray(ops.preproc(jnp.asarray(recs), 13, 1000))
    got = [np.asarray(ops.preproc_tile(jnp.asarray(recs[lo:lo + 32]),
                                       13, 1000, tile_recs=32))
           for lo in range(0, 77, 32)]
    np.testing.assert_array_equal(np.concatenate(got), want)
    with pytest.raises(ValueError, match="tile carries"):
        preproc_tile(jnp.asarray(recs), 13, 1000, tile_recs=32)


def test_preproc_semantics():
    recs = np.array([[-5, 0, 99, 12345]], np.int32)
    out = np.asarray(ops.preproc(jnp.asarray(recs), 3, 100, impl="pallas"))
    dense = out[:, :3].view(np.float32)[0]
    np.testing.assert_allclose(dense, [0.0, 0.0, np.log1p(99)], rtol=1e-6)
    assert out[0, 3] == 12345 % 100


# ---------------------------------------------------------------------------
# Segmented reduce (collective offload math)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 8), words=st.integers(1, 1200),
       seed=st.integers(0, 2**31))
def test_chunk_reduce_pallas_bit_identical_to_ref(k, words, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((k, words)).astype(np.float32)
    u8 = np.ascontiguousarray(x).view(np.uint8).reshape(k, words * 4)
    a = np.asarray(ops.chunk_reduce(jnp.asarray(u8), impl="pallas"))
    b = np.asarray(ops.chunk_reduce(jnp.asarray(u8), impl="ref"))
    assert (a == b).all()
    # and the ref is the honest left fold
    acc = jnp.asarray(x[0])
    for i in range(1, k):
        acc = acc + x[i]
    assert (b.view(np.float32) == np.asarray(acc)).all()


def test_chunk_reduce_int32_exact():
    rng = np.random.default_rng(0)
    x = rng.integers(-(1 << 20), 1 << 20, (5, 333), dtype=np.int32)
    u8 = x.view(np.uint8).reshape(5, 333 * 4)
    for impl in ("pallas", "ref"):
        out = np.asarray(ops.chunk_reduce(jnp.asarray(u8), dtype="int32",
                                          impl=impl))
        assert (out.view(np.int32) == x.sum(0, dtype=np.int32)).all()


def test_chunk_reduce_order_matters_and_is_pinned():
    """Float fold order is part of the contract: reversing the rows
    changes bits (non-associativity is real on this data), while the
    same rows always fold identically."""
    rng = np.random.default_rng(4)
    x = (rng.standard_normal((4, 256)) * 10.0**rng.integers(
        -6, 6, (4, 256))).astype(np.float32)
    u8 = np.ascontiguousarray(x).view(np.uint8).reshape(4, 1024)
    a = np.asarray(ops.chunk_reduce(jnp.asarray(u8), impl="ref"))
    b = np.asarray(ops.chunk_reduce(jnp.asarray(u8[::-1].copy()),
                                    impl="ref"))
    assert (a == np.asarray(ops.chunk_reduce(jnp.asarray(u8),
                                             impl="ref"))).all()
    assert not (a == b).all()
