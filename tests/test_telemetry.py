"""Unified telemetry tests: metric registry, flight recorder,
Chrome-trace export, counter conservation under loss/spray, engine
counter bit-identity, and the determinism contract (no wall-clock in
``repro.core``; two seeded runs export byte-identical traces).
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core import telemetry as tm
from repro.core.netsim import (ClosConfig, FabricConfig,
                               clos_incast_scenario, incast_scenario)
from repro.core.rdma import ENGINE_COUNTERS


# ---------------------------------------------------------------------------
# MetricRegistry
# ---------------------------------------------------------------------------

def test_typed_metrics():
    c = tm.Counter()
    c.inc()
    c.inc(4)
    assert c.snapshot() == 5
    g = tm.Gauge()
    g.set(2.5)
    assert g.snapshot() == 2.5
    h = tm.Histogram(bounds=(1, 4, 16))
    for v in (0, 1, 3, 20, 1000):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5 and s["sum"] == 1024
    assert s["min"] == 0 and s["max"] == 1000
    assert s["buckets"] == [2, 1, 0, 2]       # <=1, <=4, <=16, overflow


def test_registry_register_and_reject():
    reg = tm.MetricRegistry()
    reg.counter("a/b").inc(3)
    with pytest.raises(ValueError):
        reg.counter("a/b")                    # duplicate
    for bad in ("", "/x", "x/"):
        with pytest.raises(ValueError):
            reg.register(bad, tm.Counter())
    assert reg.paths() == ["a/b"]


def test_registry_snapshot_flat_diff():
    reg = tm.MetricRegistry()
    c = reg.counter("net/tx")
    reg.gauge("net/depth", 7)
    reg.register("node", lambda: {"stats": {"rx": 2, "lst": [1, 2]}})
    c.inc(10)
    snap = reg.snapshot()
    assert snap == {"net": {"tx": 10, "depth": 7},
                    "node": {"stats": {"rx": 2, "lst": [1, 2]}}}
    flat = reg.flat(snap)
    assert flat == {"net/tx": 10, "net/depth": 7, "node/stats/rx": 2,
                    "node/stats/lst/0": 1, "node/stats/lst/1": 2}
    c.inc(5)
    d = reg.diff(snap, reg.snapshot())
    assert d["net/tx"] == 5 and d["node/stats/rx"] == 0


# ---------------------------------------------------------------------------
# FlightRecorder
# ---------------------------------------------------------------------------

def test_recorder_ring_bounds_and_counts():
    rec = tm.FlightRecorder(capacity=4)
    for i in range(10):
        rec.record(i, "inject", ("node", 0), psn=i)
    assert rec.total_events == 10
    assert rec.dropped_events == 6
    assert len(rec.events()) == 4
    assert [e.tick for e in rec.events()] == [6, 7, 8, 9]
    # monotonic per-kind counts are wrap-independent
    assert rec.counts["inject"] == 10
    snap = rec.snapshot()
    assert snap["events_total"] == 10 and snap["events_retained"] == 4
    rec.clear()
    assert rec.total_events == 0 and not rec.events()


def test_chrome_trace_phases_and_tracks():
    rec = tm.FlightRecorder()
    rec.record(1, "enqueue", ("port", 0), qpn=1, psn=0)
    rec.record(1, "qdepth", ("port", 0), depth=3)
    rec.record(2, "coll_transfer", ("coll", "world4"), dur=5, sends=2)
    rec.record(3, "retransmit", ("qp", "1:7"), psn=9)
    doc = rec.chrome_trace(tick_us=2)
    evs = doc["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # process/thread metadata for 3 categories + 3 threads
    names = {e["args"]["name"] for e in by_ph["M"]
             if e["name"] == "process_name"}
    assert names == {"port", "coll", "qp"}
    [cnt] = by_ph["C"]
    assert cnt["name"] == "qdepth" and cnt["args"]["depth"] == 3
    [span] = by_ph["X"]
    assert span["ts"] == 4 and span["dur"] == 10   # tick_us scaling
    assert span["args"] == {"sends": 2}            # dur lifted out
    assert {e["name"] for e in by_ph["i"]} == {"enqueue", "retransmit"}
    json.loads(rec.chrome_trace_json())            # serializable


def test_chrome_trace_export_roundtrip(tmp_path):
    rec = tm.FlightRecorder()
    res = incast_scenario(2, message_bytes=8192, recorder=rec)
    path = tmp_path / "trace.json"
    n = rec.export_chrome_trace(str(path))
    assert n == len(rec.events()) > 0
    doc = json.loads(path.read_text())
    assert doc["otherData"]["clock"] == "sim_ticks"
    assert any(e["ph"] == "C" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# Determinism contract
# ---------------------------------------------------------------------------

def test_core_determinism_lint_clean():
    """The simulator's only clock is the integer tick and its only
    randomness is seeded streams: the balint determinism pass (wall
    clock, unseeded RNG, set/dict iteration order on wire paths,
    mutable defaults — see docs/BALINT.md) must report zero violations
    over ``repro.core``.  Supersedes the old ad-hoc wall-clock grep."""
    from repro.analysis import run_analysis
    report = run_analysis(paths=["src/repro/core"],
                          passes=["determinism"])
    assert not report.violations, "\n".join(
        f"{v.path}:{v.line}: [{v.rule}] {v.message}"
        for v in report.violations)


def _traced_run():
    rec = tm.FlightRecorder()
    clos_incast_scenario(3, message_bytes=16384, fail_spine_at=10,
                         recorder=rec)
    return rec.chrome_trace_json()


def test_trace_byte_identical_across_runs():
    assert _traced_run() == _traced_run()


# ---------------------------------------------------------------------------
# Engine-carried counters (the ecn_cnt pattern)
# ---------------------------------------------------------------------------

def test_engine_counter_columns_zero_initialized():
    t = pipe.make_rx_tables(4)
    for col in pipe.COUNTER_FIELDS:
        arr = np.asarray(getattr(t, col))
        assert arr.shape == (4,) and arr.dtype == np.int32
        assert (arr == 0).all()


def test_engine_counters_match_outputs():
    """Counter columns must reconcile with the per-packet outputs the
    same pipeline call returns — on both engines."""
    rng = np.random.default_rng(7)
    n_pkts, n_qps = 64, 5
    pkts = []
    nxt = {}
    for _ in range(n_pkts):
        q = int(rng.integers(0, n_qps))
        p0 = nxt.get(q, 0)
        use = p0 if rng.random() < 0.7 else max(0, p0 - 1)
        if use == p0:
            nxt[q] = p0 + 1
        pkts.append(pk.Packet(opcode=pk.WRITE_ONLY, qpn=q, psn=use,
                              payload=np.zeros(32, np.uint8), dma_len=32))
    batch = {k: jnp.asarray(v)
             for k, v in pk.batch_from_packets(pkts, mtu=256).items()}
    t0 = pipe.make_rx_tables(n_qps)
    for fn in (pipe.rx_pipeline, pipe.rx_pipeline_batched):
        t1, r = fn(pipe.clone_tables(t0), batch)  # engines donate arg 0
        assert int(np.asarray(t1.acc_cnt).sum()) == \
            int(np.asarray(r.accept).sum())
        assert int(np.asarray(t1.ecn_tot).sum()) == \
            int(np.asarray(r.ecn_cnt).sum())


def test_engine_totals_match_host_stats_under_loss():
    """The jitted engine's carried counters, harvested once at snapshot
    time, must agree exactly with the host-side ``NodeStats`` — for
    every mapped counter, on a lossy run that exercises dup/ooo paths."""
    res = incast_scenario(
        4, message_bytes=32768,
        fabric_cfg=FabricConfig(port_bandwidth=2, port_delay=2,
                                queue_capacity=8, seed=3))
    for node in [res.receiver] + res.senders:
        totals = node.engine_totals()
        for host_name, val in totals.items():
            assert val == getattr(node.stats, host_name), (
                f"node {node.node_id}: engine {host_name}={val} != host "
                f"stats {getattr(node.stats, host_name)}")
    assert res.receiver.engine_totals()["accepted"] > 0


# ---------------------------------------------------------------------------
# Conservation + event reconciliation under random loss/spray
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31), st.integers(2, 4),
       st.sampled_from([0.0, 0.02, 0.05]),
       st.sampled_from(["spray", "ecmp"]),
       st.sampled_from(["selective_repeat", "go_back_n"]))
def test_counters_reconcile_random_loss_spray(seed, fan_in, loss, path,
                                              rx_mode):
    """Packet conservation: every injected packet is delivered, dropped,
    or still in flight; retransmit stats match recorded retransmit
    events exactly."""
    rec = tm.FlightRecorder(capacity=1 << 18)
    cfg = ClosConfig(nodes_per_leaf=1, n_spines=2, port_bandwidth=4,
                     port_delay=1, queue_capacity=48, spine_delay=(1, 5),
                     loss_prob=loss, seed=seed % 997,
                     path_mode=path)
    res = clos_incast_scenario(fan_in, message_bytes=8192, clos_cfg=cfg,
                               rx_mode=rx_mode, path_select=path,
                               recorder=rec)
    reg, _ = tm.instrument(fabric=res.fabric,
                           nodes=[res.receiver] + res.senders,
                           recorder=rec)
    snap = reg.snapshot()
    fab = snap["fabric"]
    dropped = (fab["ports"]["wire_dropped"] + fab["ports"]["tail_dropped"]
               + fab["uplinks"]["wire_dropped"]
               + fab["uplinks"]["tail_dropped"]
               + fab["spine_down"]["wire_dropped"]
               + fab["spine_down"]["tail_dropped"]
               + fab["failure_dropped"])
    assert fab["injected"] == (dropped + fab["ports"]["delivered"]
                               + fab["in_flight"]), \
        "packet conservation violated"
    by = snap["flight"]["by_kind"]
    retx = sum(n["retx"]["retransmissions"]
               for k, n in snap.items() if k.startswith("node"))
    stats_retx = sum(s.stats.retransmissions
                     for s in [res.receiver] + res.senders)
    assert by.get("retransmit", 0) == stats_retx
    assert retx >= stats_retx          # buffer counts staged resends too
    # every send recorded either an inject or a wire_drop event
    assert by.get("inject", 0) + by.get("wire_drop", 0) == fab["injected"]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 24), st.integers(1, 96))
def test_counter_columns_scan_vs_batched(seed, n_qps, n_pkts):
    """The five counter columns are part of the carried state, so the
    batched engine must produce bit-identical arrays to the scan
    oracle — including on traces with dup/gap/invalid lanes."""
    rng = np.random.default_rng(seed)
    pkts, nxt = [], {}
    for _ in range(n_pkts):
        q = int(rng.integers(0, n_qps))
        p0 = nxt.get(q, 0)
        r = rng.random()
        if r < 0.6:
            use, nxt[q] = p0, p0 + 1
        elif r < 0.8:
            use = max(0, p0 - int(rng.integers(1, 3)))
        else:
            use = p0 + int(rng.integers(1, 3))
        pkts.append(pk.Packet(opcode=pk.WRITE_ONLY, qpn=q, psn=use,
                              payload=np.zeros(16, np.uint8), dma_len=16))
    b = pk.batch_from_packets(pkts, mtu=256)
    b["valid"][rng.random(n_pkts) < 0.15] = 0      # invalid lanes
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    t0 = pipe.make_rx_tables(n_qps, initial_credits=4)
    ta, _ = pipe.rx_pipeline(pipe.clone_tables(t0), batch)
    tb, _ = pipe.rx_pipeline_batched(t0, batch)
    for col in pipe.COUNTER_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ta, col)), np.asarray(getattr(tb, col)),
            err_msg=f"counter column {col}")


# ---------------------------------------------------------------------------
# The acceptance scenario: 8:1 incast + mid-run spine failure
# ---------------------------------------------------------------------------

def test_incast_spine_failure_trace_reconciles(tmp_path):
    """Perfetto trace of the 8:1 incast with a mid-run spine failure:
    the export is valid JSON and its event counts reconcile exactly
    with the MetricRegistry snapshot."""
    rec = tm.FlightRecorder(capacity=1 << 20)
    res = clos_incast_scenario(8, message_bytes=16384, fail_spine_at=10,
                               recorder=rec)
    reg, _ = tm.instrument(fabric=res.fabric,
                           nodes=[res.receiver] + res.senders,
                           recorder=rec)
    snap = reg.snapshot()
    assert rec.dropped_events == 0
    by = snap["flight"]["by_kind"]
    assert by["inject"] == snap["fabric"]["injected"]
    assert by.get("enqueue", 0) == \
        by.get("dequeue", 0) + by.get("flush", 0)
    assert by.get("spine_fail", 0) == 1
    assert snap["fabric"]["alive_spines"] == 1
    # every trace event is retained, so the exported JSON has exactly
    # the registry's total (plus track metadata records)
    path = tmp_path / "incast.json"
    rec.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    data_events = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert len(data_events) == snap["flight"]["events_total"]
    assert sum(by.values()) == snap["flight"]["events_total"]
