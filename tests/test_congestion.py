"""Congestion-control property suite (ECN marking -> CNP -> DCQCN).

Properties (via tests/_hyp.py — hypothesis when installed, seeded fixed
examples otherwise):
  * the DCQCN rate stays inside [min_rate, line_rate] under any event
    sequence, and the token bucket never goes negative or over-fills;
  * a CNP never advances cumulative-ACK state: no retransmission slot is
    released, no flow-control budget returned, no completion signalled;
  * the batched RX engine stays bit-identical to the per-packet oracle
    under random ECN marking (+ dup/gap traffic), including the per-QP
    ``ecn_cnt`` reduction — and end-to-end on a lossy ECN fabric;
  * 8:1 incast with DCQCN converges to >= 80% aggregate goodput with
    zero drop-tail deaths (no QP exhausts its retry budget).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.flow_control import (AckClockedFlowControl, DcqcnConfig,
                                     DcqcnRateController, FlowControlConfig)
from repro.core.netsim import (FabricConfig, SwitchedFabric,
                               dcqcn_fabric_profile, incast_scenario)
from repro.core.rdma import RdmaNode, run_network


# ---------------------------------------------------------------------------
# DCQCN rate-controller invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["cnp", "tick", "take"]),
                          st.integers(1, 6)), max_size=300),
       st.integers(1, 8), st.integers(1, 40))
def test_dcqcn_rate_and_token_bounds(events, line_rate, min_rate_pct):
    """INVARIANT: min_rate <= rate <= line_rate and 0 <= tokens <= burst
    at every point, whatever the CNP/timer interleaving."""
    cfg = DcqcnConfig(line_rate=float(line_rate),
                      min_rate=line_rate * min_rate_pct / 100.0)
    rc = DcqcnRateController(2, cfg, burst=16.0)
    rc.activate(0)
    now = 0
    for kind, n in events:
        if kind == "cnp":
            rc.on_cnp(0, now)
        elif kind == "take":
            rc.take(0, n)
        else:
            for _ in range(n):
                now += 1
                rc.tick(now)
        assert cfg.min_rate <= rc.rate[0] <= cfg.line_rate + 1e-9
        assert cfg.min_rate <= rc.target[0] <= cfg.line_rate + 1e-9
        assert 0.0 <= rc.alpha[0] <= 1.0
        assert 0.0 <= rc.tokens[0] <= rc.burst + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["req", "ack", "cnp", "tick"]),
                          st.integers(1, 8)), max_size=200),
       st.integers(1, 32))
def test_dcqcn_flow_control_invariants(events, window):
    """The ACK-clock invariants survive rate pacing: outstanding never
    exceeds the window, nothing is ever dropped (only delayed)."""
    fc = AckClockedFlowControl(2, FlowControlConfig(
        window, congestion_control="dcqcn",
        dcqcn=DcqcnConfig(line_rate=4.0)))
    submitted = passed = 0
    now = 0
    for kind, n in events:
        n = min(n, window)
        if kind == "req":
            submitted += 1
            passed += len(fc.request(0, n))
        elif kind == "ack":
            passed += len(fc.ack(0, n))
        elif kind == "cnp":
            fc.on_cnp(0, now)
        else:
            for _ in range(n):
                now += 1
                passed += len(fc.tick(now))
        assert fc.outstanding[0] <= window
        assert fc.budget[0] >= 0
    # pacing delays, never drops: whatever has not passed is still queued
    assert passed + fc.queue_depth(0) == submitted


def test_dcqcn_rate_recovers_after_cut():
    """Fast recovery + additive increase climb back toward line rate
    once CNPs stop."""
    cfg = DcqcnConfig(line_rate=4.0)
    rc = DcqcnRateController(1, cfg)
    rc.activate(0)
    for now in range(1, 20):
        rc.tick(now)
    rc.on_cnp(0, 20)
    cut = rc.rate[0]
    assert cut < 4.0
    for now in range(21, 1600):
        rc.tick(now)
    assert rc.rate[0] > cut
    assert rc.rate[0] >= 0.9 * cfg.line_rate     # climbed nearly back
    assert rc.alpha[0] < 0.1                     # congestion estimate decayed


# ---------------------------------------------------------------------------
# CNPs never ACK data
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20))
def test_cnp_never_acks_data(n_cnps):
    """PROPERTY: delivering any number of CNPs to a sender with unacked
    data releases no retransmission slot, returns no flow-control
    budget, and completes no message."""
    fab = SwitchedFabric(2, FabricConfig(port_bandwidth=4, port_delay=1,
                                         loss_prob=1.0, seed=1))  # black hole
    a = RdmaNode(0, fab, fc_window=8, congestion_control="dcqcn")
    b = RdmaNode(1, fab)
    qpn, _, _ = a.init_rdma(1 << 16, b)
    data = np.arange(5 * pk.MTU, dtype=np.uint8)
    a.rdma_write(qpn, data)
    # drain pacing so some packets actually left (and were eaten)
    for _ in range(16):
        fab.tick()
        a.tick()
    held = a.retx.outstanding(qpn)
    assert held > 0
    outstanding = a.fc.outstanding[qpn]
    budget = a.fc.budget[qpn]
    completed = a.check_completed(qpn)
    epsn = int(a.rx_tables.epsn[qpn])
    for _ in range(n_cnps):
        a.on_packets([pk.make_cnp(qpn)])
    assert a.retx.outstanding(qpn) == held
    assert a.fc.outstanding[qpn] == outstanding
    assert a.fc.budget[qpn] == budget
    assert a.check_completed(qpn) == completed
    assert int(a.rx_tables.epsn[qpn]) == epsn
    assert a.stats.cnp_rx == n_cnps
    # ... but the rate controller did react
    assert a.fc.rate.rate[qpn] < a.fc.rate.cfg.line_rate


# ---------------------------------------------------------------------------
# Batched engine == oracle under ECN marking
# ---------------------------------------------------------------------------

def _random_ecn_trace(rng, n_qps, n_pkts):
    """In-seq / dup / gap traffic with random CE marks."""
    pkts, psn = [], {}
    for _ in range(n_pkts):
        q = int(rng.integers(0, n_qps))
        p0 = psn.get(q, 0)
        r = rng.random()
        if r < 0.6:
            use, psn[q] = p0, p0 + 1
        elif r < 0.8:
            use = max(0, p0 - int(rng.integers(1, 3)))
        else:
            use = p0 + int(rng.integers(1, 3))
        plen = int(rng.integers(1, 200))
        op = int(rng.choice([pk.WRITE_ONLY, pk.WRITE_FIRST,
                             pk.WRITE_MIDDLE, pk.WRITE_LAST]))
        pkts.append(pk.Packet(opcode=op, qpn=q, psn=use,
                              payload=np.zeros(plen, np.uint8),
                              vaddr=int(rng.integers(0, 4096)),
                              dma_len=plen, ecn=bool(rng.random() < 0.4)))
    return pkts


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 32), st.integers(1, 120),
       st.integers(0, 8))
def test_rx_engines_bit_identical_under_ecn(seed, n_qps, n_pkts, pad):
    rng = np.random.default_rng(seed)
    b = pk.batch_from_packets(_random_ecn_trace(rng, n_qps, n_pkts), mtu=256)
    if pad:                                # trailing invalid lanes
        for k, v in b.items():
            b[k] = np.concatenate([v, np.zeros((pad,) + v.shape[1:],
                                               v.dtype)])
        b["valid"][n_pkts:] = 0
        b["ecn"][n_pkts:] = 1              # CE on dead lanes must not count
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    t0 = pipe.make_rx_tables(n_qps, initial_credits=5)
    # engines donate their tables arg — clone so both see the same t0
    ta, ra = pipe.rx_pipeline(pipe.clone_tables(t0), batch)
    tb, rb = pipe.rx_pipeline_batched(t0, batch)
    for f in pipe.RxTables._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ta, f)), np.asarray(getattr(tb, f)),
            err_msg=f"tables.{f}")
    for f in pipe.RxResult._fields:
        a_, b_ = np.asarray(getattr(ra, f)), np.asarray(getattr(rb, f))
        if f == "ecn_cnt":                 # (Q,): compare in full
            np.testing.assert_array_equal(a_, b_, err_msg="result.ecn_cnt")
        else:
            np.testing.assert_array_equal(a_[:n_pkts], b_[:n_pkts],
                                          err_msg=f"result.{f}")
    # the reduction is consistent with the per-packet echoes
    want = np.zeros(n_qps, np.int32)
    np.add.at(want, b["qpn"][:n_pkts][np.asarray(ra.ecn_echo)[:n_pkts]], 1)
    np.testing.assert_array_equal(np.asarray(ra.ecn_cnt), want)


def _run_ecn_lossy(engine: str):
    """Lossy ECN fabric + DCQCN senders, one engine."""
    fab = SwitchedFabric(2, FabricConfig(
        port_bandwidth=4, port_delay=2, queue_capacity=16,
        loss_prob=0.05, ecn_kmin=2, ecn_kmax=8, ecn_pmax=0.25, seed=23))
    a = RdmaNode(0, fab, fc_window=16, engine=engine,
                 congestion_control="dcqcn")
    b = RdmaNode(1, fab, fc_window=16, engine=engine,
                 congestion_control="dcqcn")
    rng = np.random.default_rng(29)
    qps = [a.init_rdma(1 << 16, b)[0] for _ in range(3)]
    datas = [rng.integers(0, 256, 15_000 + 997 * i, dtype=np.uint8)
             for i in range(3)]
    for q, d in zip(qps, datas):
        a.rdma_write(q, d)
    run_network([a, b], max_ticks=120_000)
    bufs = [b._qp_buffer[i + 1][1][:len(d)].copy()
            for i, d in enumerate(datas)]
    return bufs, datas, (a.stats, b.stats), b.rx_tables


def test_engines_identical_end_to_end_with_ecn():
    """Same lossy ECN-marking trace, both engines: identical delivery,
    CNP/ECN stats and final RX tables (the PR's bit-identity criterion
    extended to the congestion loop)."""
    bufs_s, datas, stats_s, tbl_s = _run_ecn_lossy("scan")
    bufs_b, _, stats_b, tbl_b = _run_ecn_lossy("batched")
    for bs, bb, d in zip(bufs_s, bufs_b, datas):
        np.testing.assert_array_equal(bs, d)
        np.testing.assert_array_equal(bb, d)
    assert stats_s == stats_b              # includes ecn_marked_rx/cnp_tx/rx
    assert stats_s[1].cnp_tx > 0           # the loop actually fired
    for f in pipe.RxTables._fields:
        np.testing.assert_array_equal(np.asarray(getattr(tbl_s, f)),
                                      np.asarray(getattr(tbl_b, f)),
                                      err_msg=f"rx_tables.{f}")


# ---------------------------------------------------------------------------
# Incast convergence (the tentpole's end-to-end acceptance property)
# ---------------------------------------------------------------------------

def test_incast_dcqcn_converges():
    """8:1 incast with DCQCN: >= 80% aggregate goodput, exact delivery,
    and zero drop-tail deaths (no QP exhausts its retry budget)."""
    message_bytes = 1 << 20
    res = incast_scenario(8, message_bytes=message_bytes,
                          congestion_control="dcqcn")
    line = 4 * pk.MTU                      # hot-port drain, payload B/tick
    goodput = 8 * message_bytes / max(res.ticks, 1)
    for i, data in enumerate(res.payloads):
        np.testing.assert_array_equal(
            res.receiver._qp_buffer[i + 1][1][:len(data)], data,
            err_msg=f"sender {i}")
    assert goodput / line >= 0.80, (
        f"DCQCN incast converged to only {goodput / line:.1%} of line rate")
    assert all(not s.retx.exhausted for s in res.senders), "a flow died"
    assert not res.senders[0].qp_errors
    # the control loop was genuinely exercised
    assert res.receiver.stats.cnp_tx > 0
    assert sum(s.stats.cnp_rx for s in res.senders) > 0
    assert res.fabric.port_stats[0].ecn_marked > 0


def test_incast_dcqcn_beats_ack_clocked():
    """The acceptance comparison at 8:1 on one identical fabric:
    strictly fewer drop-tail drops and >= 1.3x goodput."""
    fab_cfg = dcqcn_fabric_profile()
    runs = {}
    for cc in ("ack_clocked", "dcqcn"):
        res = incast_scenario(8, message_bytes=1 << 20, fabric_cfg=fab_cfg,
                              congestion_control=cc)
        runs[cc] = (res.fabric.port_stats[0].tail_dropped, res.ticks)
    drops_off, ticks_off = runs["ack_clocked"]
    drops_on, ticks_on = runs["dcqcn"]
    assert drops_on < drops_off, (drops_on, drops_off)
    assert ticks_off / ticks_on >= 1.3, (ticks_off, ticks_on)
