"""Optional-hypothesis shim: real property testing when `hypothesis` is
installed, a deterministic fixed-example fallback when it is not (the
bare dry-run container has no hypothesis, and the tier-1 suite must
still run there).

Usage in tests:

    from _hyp import given, settings, st

The fallback implements just the strategy surface this repo uses
(integers / sampled_from / lists / tuples) and runs each ``@given`` test
over a fixed number of seeded random examples — weaker than hypothesis
(no shrinking, no example database) but the same invariants get
exercised.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    import hypothesis as _hypothesis
    HAVE_HYPOTHESIS = True
    # Suite-wide CI profile, loaded by tests/conftest.py importing this
    # module before collection (pytest.ini documents the wiring).  Two
    # choices, both anti-flake: ``deadline=None`` because property
    # suites drive whole jitted epochs and a per-example wall-clock
    # deadline on a slow shared runner is pure flake surface; and
    # ``derandomize=True`` so the example stream is a fixed function of
    # the test body — an explicit seed, no ambient randomness — and any
    # CI failure replays locally bit-for-bit.
    _hypothesis.settings.register_profile(
        "balboa", deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much],
        print_blob=True)
    _hypothesis.settings.load_profile("balboa")
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(elements):
        els = list(elements)
        return _Strategy(lambda rng: els[int(rng.integers(len(els)))])

    def _lists(elements, min_size=0, max_size=None):
        cap = 10 if max_size is None else max_size
        return _Strategy(lambda rng: [
            elements.draw(rng)
            for _ in range(int(rng.integers(min_size, cap + 1)))])

    def _tuples(*els):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in els))

    class st:                                        # noqa: N801
        integers = staticmethod(_integers)
        sampled_from = staticmethod(_sampled_from)
        lists = staticmethod(_lists)
        tuples = staticmethod(_tuples)

    def given(*gargs, **gkw):
        def deco(fn):
            # deliberately *not* functools.wraps: pytest must see a
            # zero-arg callable, not the strategy-filled signature
            def runner():
                rng = _np.random.default_rng(0xBA1B0A)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(*[s.draw(rng) for s in gargs],
                       **{k: s.draw(rng) for k, s in gkw.items()})
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    def settings(*args, **kwargs):
        if args and callable(args[0]):               # bare @settings
            return args[0]
        return lambda fn: fn
