"""Suite-wide pytest wiring.

Importing ``_hyp`` here applies the repo's hypothesis profile
("balboa": ``deadline=None`` + ``derandomize=True``) to every test
module before collection — real-hypothesis CI runs and the
no-hypothesis fallback container take the same code path, so the
property suites (tests/test_fused_core.py and friends) can never flake
on a per-example deadline or an ambient random seed.  Kept out of
``addopts`` deliberately: ``--hypothesis-profile`` only parses when the
hypothesis pytest plugin is installed, and tier-1 must still run on the
bare container without it.
"""
import _hyp  # noqa: F401  (registers + loads the profile on import)
