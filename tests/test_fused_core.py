"""Oracle pinning for the fused epoch core (``repro.core.fused``).

The fused core runs whole simulator epochs inside one jitted
``while_loop`` on device; the per-tick Python netsim stays the oracle.
This suite is the trust anchor: property-tested random schedules (wire
loss, duplication via timeout retransmits, ECN thresholds, reorder
spray, QP counts, mid-flight packing) assert the fused epoch leaves the
ENTIRE Python world — RX tables, retransmit slots, flow control, credit
ledgers, per-QP completion/progress maps, delivered buffer bytes, node
stats, fabric port stats, wire/queue contents — bit-identical to
stepping the same world per-tick, for both ``go_back_n`` and
``selective_repeat`` RX modes, on both the switched star fabric and the
point-to-point link mesh.

Strictness: for every schedule drawn here the world is fusable by
construction, and the tests assert ``run_fused_epoch`` did NOT fall
back — a silently widened bail-out gate fails the suite instead of
quietly shifting coverage back to the per-tick path.

Equivalence excludes exactly three kinds of private state, all
re-derived before their next use: numpy ``Generator`` objects (chaos
mode replaces their draws), the per-tick chaos rank cursors
(``_ctick``/``_csend``/``_cpop``/``_cidx``, reset at the next tick
boundary), and queue ``on_event`` hooks (packing bails when one is
installed).
"""
import copy
import sys

import numpy as np

from _hyp import given, settings, st

from repro.core import fused
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.netsim import (FabricConfig, LinkConfig, Network,
                               SwitchedFabric)
from repro.core.rdma import RdmaNode, run_network, step_network

MTU = 256                     # small MTU => multi-packet, multi-chunk plans


# ---------------------------------------------------------------------------
# full-world snapshot / structural diff
# ---------------------------------------------------------------------------

def _pkt_tuple(p):
    pay = None if p.payload is None or p.payload.size == 0 \
        else bytes(np.asarray(p.payload, np.uint8).tobytes())
    return (p.src_ip, p.dst_ip, p.src_port, p.dst_port, p.opcode, p.qpn,
            p.psn, bool(p.ack_req), p.vaddr, p.rkey, p.dma_len, p.ack_psn,
            p.msn, p.sack_bits, p.path_id, p.icrc, bool(p.dpi_flag),
            bool(p.ecn), p.coll_tag, p.coll_src, p.coll_nsrc, p.coll_frag,
            pay)


def snap_node(n):
    d = {}
    d["stats"] = dict(vars(n.stats))
    d["rx_tables"] = {f: np.asarray(getattr(n.rx_tables, f)).copy()
                      for f in pipe.RxTables._fields}
    d["npsn"] = list(n.qp.tables.npsn)
    d["retx_slots"] = {q: {psn: (_pkt_tuple(s.packet), s.deadline,
                                 s.retries)
                           for psn, s in slots.items()}
                       for q, slots in n.retx.slots.items()}
    d["retx_retrans"] = n.retx.retransmissions
    d["fc"] = (list(n.fc.budget), list(n.fc.outstanding),
               [len(q) for q in n.fc.pending], n.fc.total_passed)
    d["credits"] = (list(n.credits.credits), n.credits.accepted,
                    n.credits.granted, n.credits.dropped_no_credit,
                    list(n.credits.accepted_per_qp),
                    list(n.credits.dropped_per_qp))
    d["rx_progress"] = dict(n._rx_progress)
    d["completions"] = dict(n._completions)
    d["sr_pending_last"] = {k: list(v)
                            for k, v in n._sr_pending_last.items()}
    d["sr_pend"] = {k: dict(v) for k, v in n._sr_pend.items()}
    d["last_nak"] = dict(n._last_nak_resend)
    d["last_gap"] = dict(n._last_gap_resend)
    d["last_cnp"] = dict(n._last_cnp_sent)
    d["qp_errors"] = sorted(n.qp_errors)
    d["bufs"] = {q: bytes(b.tobytes())
                 for q, (_rk, b) in n._qp_buffer.items()}
    return d


def snap_net(net):
    d = {"now": net.now}
    if isinstance(net, SwitchedFabric):
        d["seq"] = net._seq
        d["injected"] = net.injected
        d["wire"] = sorted((a, s, dst, _pkt_tuple(p))
                           for a, s, dst, p in net._wire)
        d["rings"] = [[_pkt_tuple(p) for p, _m in eg._q]
                      for eg in net.egress]
        d["port_stats"] = [dict(vars(st_)) for st_ in net.port_stats]
    else:
        d["links"] = {
            k: {"seq": lk._seq, "sent": lk.sent, "dropped": lk.dropped,
                "heap": sorted((a, s, _pkt_tuple(p))
                               for a, s, p in lk._heap)}
            for k, lk in net.links.items()}
    return d


def snap(nodes):
    return {"nodes": [snap_node(n) for n in nodes],
            "net": snap_net(nodes[0].net)}


def diff(a, b, path=""):
    """Recursive structural diff; returns human-readable mismatch lines
    (empty list == bit-identical)."""
    out = []
    if isinstance(a, dict):
        for k in sorted(set(a) | set(b), key=repr):
            if k not in a:
                out.append(f"{path}.{k}: missing in oracle")
            elif k not in b:
                out.append(f"{path}.{k}: missing in fused")
            else:
                out += diff(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        if len(a) != len(b):
            out.append(f"{path}: len {len(a)} vs {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            out += diff(x, y, f"{path}[{i}]")
    elif isinstance(a, np.ndarray):
        if not np.array_equal(a, b):
            idx = np.nonzero(a != b)[0][:5]
            out.append(f"{path}: arrays differ at {idx} "
                       f"a={a[idx]} b={b[idx]}")
    elif a != b:
        out.append(f"{path}: {a!r} vs {b!r}")
    return out


def assert_fused_matches_oracle(nodes, max_ticks=100_000, idle_done=8,
                                watermarks=None, expect_fused=True):
    """Run one fused epoch on ``nodes`` and the same number of per-tick
    oracle steps on a deepcopy; assert the two worlds are bit-identical.
    Returns the fused result dict (or None when ``expect_fused`` is
    False and the world legitimately does not pack)."""
    oracle = copy.deepcopy(nodes)
    res = fused.run_fused_epoch(nodes, max_ticks=max_ticks,
                                idle_done=idle_done, watermarks=watermarks)
    if res is None:
        assert not expect_fused, "schedule was expected to pack+fuse"
        return None
    assert expect_fused
    for _ in range(res["steps"]):
        step_network(oracle)
    d = diff(snap(oracle), snap(nodes))
    assert not d, "fused epoch diverged from per-tick oracle:\n  " \
        + "\n  ".join(d[:40])
    return res


# ---------------------------------------------------------------------------
# world builders (fusable by construction)
# ---------------------------------------------------------------------------

def build_star(seed, *, sr=False, loss=0.0, kmax=0, nbytes=2000,
               n_senders=2, bw=3, cap=16, window=16, presteps=0,
               extra_qps=0):
    cfg = FabricConfig(port_bandwidth=bw, port_delay=2,
                       queue_capacity=cap, loss_prob=loss,
                       ecn_kmin=4, ecn_kmax=kmax, seed=seed % 1000,
                       chaos_seed=seed if (loss or kmax) else None)
    fab = SwitchedFabric(n_senders + 1, cfg)
    mode = "selective_repeat" if sr else "go_back_n"
    kw = dict(fc_window=window, rx_mode=mode, n_qps=32, mtu=MTU)
    recv = RdmaNode(0, fab, **kw)
    senders = [RdmaNode(i + 1, fab, **kw) for i in range(n_senders)]
    rng = np.random.default_rng(seed)
    for i, s in enumerate(senders):
        for j in range(1 + (extra_qps if i == 0 else 0)):
            q, _rk, _buf = s.init_rdma(1 << 16, recv)
            s.rdma_write(q, rng.integers(
                0, 256, max(nbytes + 777 * i - 301 * j, 1),
                dtype=np.uint8))
    nodes = [recv] + senders
    for _ in range(presteps):
        step_network(nodes)
    return nodes


def build_p2p(seed, *, sr=False, loss=0.0, reorder=0.0, jitter=0,
              nbytes=2000, latency=2, bw=0, window=16, presteps=0,
              n_flows=2):
    chaos = seed if (loss or reorder or jitter) else None
    cfg = LinkConfig(loss_prob=loss, reorder_prob=reorder,
                     jitter_ticks=jitter, latency_ticks=latency,
                     bandwidth_pkts_per_tick=bw, seed=seed % 1000,
                     chaos_seed=chaos)
    net = Network(2, cfg)
    mode = "selective_repeat" if sr else "go_back_n"
    kw = dict(fc_window=window, rx_mode=mode, n_qps=32, mtu=MTU)
    a, b = RdmaNode(0, net, **kw), RdmaNode(1, net, **kw)
    rng = np.random.default_rng(seed)
    for i in range(n_flows):
        q, _rk, _buf = a.init_rdma(1 << 16, b)
        a.rdma_write(q, rng.integers(0, 256, nbytes + 501 * i,
                                     dtype=np.uint8))
    nodes = [a, b]
    for _ in range(presteps):
        step_network(nodes)
    return nodes


# ---------------------------------------------------------------------------
# property suites — random schedules, bit-identity, both RX modes
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2 ** 31), st.sampled_from([0.02, 0.08, 0.15]),
       st.integers(200, 3200), st.integers(0, 24), st.integers(0, 2))
def test_star_gbn_loss_bit_identical(seed, loss, nbytes, presteps,
                                     extra_qps):
    """Star fabric, go-back-N, chaos wire loss (drops force timeout
    retransmits => the receiver sees genuine duplicates), random message
    sizes / QP counts / mid-flight pack points."""
    nodes = build_star(seed, loss=loss, nbytes=nbytes, presteps=presteps,
                       extra_qps=extra_qps)
    assert_fused_matches_oracle(nodes)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2 ** 31), st.sampled_from([0.02, 0.1]),
       st.integers(200, 3200), st.integers(0, 24))
def test_star_sr_loss_bit_identical(seed, loss, nbytes, presteps):
    """Star fabric, selective repeat: loss exercises the SACK bitmap,
    out-of-order DMA landing, gap resend and the pending-LAST flush."""
    nodes = build_star(seed, sr=True, loss=loss, nbytes=nbytes,
                       presteps=presteps)
    assert_fused_matches_oracle(nodes)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2 ** 31), st.sampled_from([6, 8, 12]),
       st.integers(500, 3200), st.integers(0, 16))
def test_star_ecn_thresholds_bit_identical(seed, kmax, nbytes, presteps):
    """Star fabric under RED/ECN marking: random Kmax thresholds, a
    shallow drop-tail queue, CNP emission + holdoff on the receiver."""
    nodes = build_star(seed, kmax=kmax, nbytes=nbytes, n_senders=2,
                       bw=2, cap=14, presteps=presteps)
    assert_fused_matches_oracle(nodes)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2 ** 31), st.sampled_from([0.02, 0.08]),
       st.sampled_from([0.1, 0.25]), st.integers(1, 3),
       st.integers(0, 24))
def test_p2p_gbn_spray_bit_identical(seed, loss, reorder, jitter,
                                     presteps):
    """Point-to-point links with chaos loss + reorder spray + jitter:
    go-back-N OOO NAKs, NAK holdoff, dup re-ACKs."""
    nodes = build_p2p(seed, loss=loss, reorder=reorder, jitter=jitter,
                      presteps=presteps)
    assert_fused_matches_oracle(nodes)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 2 ** 31), st.sampled_from([0.02, 0.08]),
       st.sampled_from([0.15, 0.3]), st.integers(1, 3),
       st.integers(0, 24))
def test_p2p_sr_spray_bit_identical(seed, loss, reorder, jitter,
                                    presteps):
    """Selective repeat under reorder spray: the bitmap advance,
    interval-merge progress tracking and SACK-driven release paths."""
    nodes = build_p2p(seed, sr=True, loss=loss, reorder=reorder,
                      jitter=jitter, presteps=presteps, bw=3)
    assert_fused_matches_oracle(nodes)


# ---------------------------------------------------------------------------
# deterministic pins
# ---------------------------------------------------------------------------

def test_zero_tick_roundtrip_is_identity():
    """max_ticks=0: pack -> epoch(0 steps) -> unpack must be a perfect
    round trip (the strongest possible layout/unpack pin)."""
    nodes = build_star(3)
    res = assert_fused_matches_oracle(nodes, max_ticks=0)
    assert res["steps"] == 0 and not res["idle_exit"]


def test_epoch_runs_to_idle_exit():
    nodes = build_star(5)
    res = assert_fused_matches_oracle(nodes)
    assert res["idle_exit"] and res["steps"] == res["ticks"] + 1
    # delivered bytes: every flow's buffer region matches what was sent
    recv = nodes[0]
    for s in nodes[1:]:
        for sq, dst in s._peer.items():
            assert dst == 0 and s.retx.slots.get(sq, {}) == {}


def test_watermark_exit_partial_epoch():
    """An armed completion watermark (the ingest micro-epoch contract)
    exits the epoch early — mid-transfer — and the partially advanced
    world still matches the oracle stepped the same number of ticks."""
    nodes = build_star(11, nbytes=3000, bw=2)
    recv, snd = nodes[0], nodes[1]
    rq = next(iter(recv._peer))
    wm = {(0, rq): 512}
    res = assert_fused_matches_oracle(nodes, watermarks=wm)
    assert res["wm_hit"] and not res["idle_exit"]
    assert recv.rx_progress(rq) >= 512
    # transfer not finished at the exit point
    assert any(snd.retx.slots.get(q) for q in snd._peer) \
        or any(len(p) for p in snd.fc.pending)


def test_unfusable_world_left_pristine():
    """A world the twin does not model (DCQCN rate state) must fall
    back with the Python objects untouched."""
    net = Network(2, LinkConfig(latency_ticks=2))
    a = RdmaNode(0, net, congestion_control="dcqcn", mtu=MTU)
    b = RdmaNode(1, net, congestion_control="dcqcn", mtu=MTU)
    q, _rk, _buf = a.init_rdma(1 << 14, b)
    a.rdma_write(q, np.arange(900, dtype=np.uint8) % 251)
    before = snap([a, b])
    assert fused.run_fused_epoch([a, b]) is None
    assert not diff(before, snap([a, b]))


def test_run_network_fused_mode_equivalent():
    """The run_network('fused') driver delivers the same bytes, stats
    and tick count as per-tick stepping on a fusable world."""
    results = {}
    for mode in ("tick", "fused"):
        nodes = build_star(17, loss=0.08, nbytes=2800)
        t = run_network(nodes, epoch_mode=mode)
        results[mode] = (t, snap(nodes))
    assert results["tick"][0] == results["fused"][0]
    d = diff(results["tick"][1], results["fused"][1])
    assert not d, "run_network fused diverged:\n  " + "\n  ".join(d[:40])


def test_engine_counter_contract_rides_the_carry():
    """PR 8 contract: engine counter columns (accepted / dup / ooo /
    credit-drop / ecn totals) are harvested at the epoch boundary and
    match the oracle's per-tick accumulation exactly."""
    nodes = build_star(23, loss=0.1, nbytes=2600, n_senders=2)
    oracle = copy.deepcopy(nodes)
    res = fused.run_fused_epoch(nodes)
    assert res is not None
    for _ in range(res["steps"]):
        step_network(oracle)
    for nd_o, nd_f in zip(oracle, nodes):
        for f in ("acc_cnt", "dup_cnt", "ooo_cnt", "cdrop_cnt",
                  "ecn_tot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(nd_o.rx_tables, f)),
                np.asarray(getattr(nd_f.rx_tables, f)), err_msg=f)
        assert vars(nd_o.stats) == vars(nd_f.stats)


if __name__ == "__main__":
    sys.exit(0)
