"""Multipath fabric + selective-repeat RX property suite.

Covers the PR's acceptance properties:
  * random spray/loss/reorder schedules over the leaf-spine fabric
    deliver payloads bit-identical to the sent data, with the batched
    engine bit-identical to the scan oracle on the same schedule;
  * the selective-repeat receive window (both engines) is bit-identical
    to a pure-python reference receiver on randomized out-of-order
    traces, and its ACK/SACK stream never acknowledges a PSN the
    receiver has not actually accepted;
  * selective repeat retransmits no more than go-back-N on the same
    schedule (and strictly less under loss-free reorder);
  * spine failure mid-transfer recovers over the surviving planes;
  * spray path hashing and the whole fabric are deterministic under a
    fixed seed (repeat-twice identity).
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.netsim import (ClosConfig, ClosFabric, LinkConfig, Network,
                               clos_incast_scenario)
from repro.core.rdma import RdmaNode, run_network

SPAN = pk.PSN_MASK + 1
HALF = pk.PSN_MASK // 2


# ---------------------------------------------------------------------------
# Pure-python reference receiver (the out-of-order oracle)
# ---------------------------------------------------------------------------

class RefSrReceiver:
    """Reference semantics of the selective-repeat receive window:
    a cumulative edge ``epsn`` plus a bitmap of out-of-order arrivals
    within ``SR_WINDOW``.  Mirrors ``pipeline._rx_decide``'s SR branch
    in plain python — the jitted engines are diffed against it."""

    def __init__(self, credits: int = 64):
        self.epsn = 0
        self.bitmap = 0
        self.credits = credits
        self.accepted_psns = set()       # every PSN ever DMA'd

    def on_packet(self, p: pk.Packet) -> dict:
        is_payload = p.opcode in pk.PAYLOAD_OPS
        d = (p.psn - self.epsn) % SPAN
        behind = d > HALF
        in_win = (not behind) and d < pipe.SR_WINDOW
        bit = (1 << d) if in_win else 0
        already = bool(self.bitmap & bit)
        fresh = in_win and not already
        accept = is_payload and fresh and self.credits > 0
        dropped = is_payload and fresh and self.credits <= 0
        dup = is_payload and (behind or already)
        ooo = is_payload and (not behind) and not in_win
        adv = 0
        if accept:
            self.credits -= 1
            self.accepted_psns.add(p.psn)
            bm = self.bitmap | bit
            while bm & 1:
                bm >>= 1
                adv += 1
            self.epsn = (self.epsn + adv) % SPAN
            self.bitmap = bm
        return {
            "accept": accept, "dup": dup, "ooo": ooo,
            "dropped_credit": dropped,
            "ack_psn": (self.epsn - 1) % SPAN,
            "sack": self.bitmap,
            "send_ack": (accept and (p.opcode in (pk.WRITE_LAST,
                                                  pk.WRITE_ONLY)
                                     or p.ack_req or d > 0 or adv > 1))
                        or dup,
        }


def _sr_trace(rng, n_pkts, mtu=64):
    """A randomized single-QP out-of-order trace: in-window shuffles,
    duplicates, and occasional beyond-window jumps.  Every packet is
    self-contained (per-packet address), as a selective-repeat sender
    emits."""
    order = np.arange(n_pkts)
    # bounded-displacement shuffle: swap within blocks of 8 (< SR_WINDOW)
    for i in range(0, n_pkts, 8):
        blk = order[i:i + 8].copy()
        rng.shuffle(blk)
        order[i:i + 8] = blk
    pkts = []
    for idx, psn in enumerate(order):
        psn = int(psn)
        r = rng.random()
        if r < 0.15 and idx > 0:                       # duplicate
            psn = int(order[int(rng.integers(0, idx))])
        elif r < 0.22:                                 # beyond-window jump
            psn = psn + pipe.SR_WINDOW + int(rng.integers(1, 5))
        plen = int(rng.integers(1, mtu + 1))
        op = int(rng.choice([pk.WRITE_ONLY, pk.WRITE_FIRST,
                             pk.WRITE_MIDDLE, pk.WRITE_LAST]))
        pkts.append(pk.Packet(opcode=op, qpn=0, psn=psn,
                              ack_req=bool(rng.random() < 0.2),
                              payload=np.zeros(plen, np.uint8),
                              vaddr=psn * mtu, dma_len=plen))
    return pkts


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31), st.integers(4, 80),
       st.sampled_from([3, 16, 64]))
def test_sr_engines_match_reference(seed, n_pkts, credits):
    """Property: on a random out-of-order trace, the scan oracle, the
    batched engine and the python reference receiver agree packet-for-
    packet — and no ACK/SACK ever covers an undelivered PSN."""
    rng = np.random.default_rng(seed)
    pkts = _sr_trace(rng, n_pkts)
    batch = {k: jnp.asarray(v)
             for k, v in pk.batch_from_packets(pkts, mtu=64).items()}
    t0 = pipe.make_rx_tables(1, initial_credits=credits)
    t0 = t0._replace(sr=jnp.ones_like(t0.sr))
    # engines donate their tables arg — clone so both see the same t0
    ta, ra = pipe.rx_pipeline(pipe.clone_tables(t0), batch)
    tb, rb = pipe.rx_pipeline_batched(t0, batch)
    for f in pipe.RxTables._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ta, f)), np.asarray(getattr(tb, f)),
            err_msg=f"tables.{f}")
    for f in pipe.RxResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, f))[:n_pkts],
            np.asarray(getattr(rb, f))[:n_pkts], err_msg=f"result.{f}")
    ref = RefSrReceiver(credits=credits)
    for i, p in enumerate(pkts):
        v = ref.on_packet(p)
        for key in ("accept", "dup", "ooo", "dropped_credit", "send_ack"):
            assert bool(np.asarray(getattr(ra, key))[i]) == v[key], \
                f"pkt {i} (psn {p.psn}): {key}"
        assert int(np.asarray(ra.ack_psn)[i]) == v["ack_psn"], f"pkt {i}"
        assert int(np.asarray(ra.sack)[i]) == v["sack"], f"pkt {i}"
        # ---- ACK/SACK soundness: only delivered PSNs are acknowledged
        if v["send_ack"]:
            ack = v["ack_psn"]
            if ack != (0 - 1) % SPAN:            # fresh QP: nothing acked
                for q in range(ack + 1):
                    assert q in ref.accepted_psns, \
                        f"cumulative ACK {ack} covers undelivered PSN {q}"
            bits, k = v["sack"] >> 1, 1
            while bits:
                if bits & 1:
                    q = (ack + 1 + k) % SPAN
                    assert q in ref.accepted_psns, \
                        f"SACK bit {k} claims undelivered PSN {q}"
                bits >>= 1
                k += 1
    assert int(np.asarray(ta.epsn)[0]) == ref.epsn
    assert int(np.asarray(ta.rxbit)[0]) == ref.bitmap


def test_sr_bitmap_never_sets_bit_zero():
    """Invariant: after any packet, bit 0 of the receive bitmap is clear
    (receiving the expected PSN advances the edge instead)."""
    rng = np.random.default_rng(5)
    t = pipe.make_rx_tables(1, initial_credits=64)
    t = t._replace(sr=jnp.ones_like(t.sr))
    for p in _sr_trace(rng, 60):
        batch = {k: jnp.asarray(v)
                 for k, v in pk.batch_from_packets([p], mtu=64).items()}
        t, _ = pipe.rx_pipeline(t, batch)
        assert int(np.asarray(t.rxbit)[0]) & 1 == 0


# ---------------------------------------------------------------------------
# End-to-end: spray schedules over the Clos fabric
# ---------------------------------------------------------------------------

def _clos_cfg(n_spines, loss, seed):
    # asymmetric spine delays: 1, 5, 9, ... ticks — genuine reorder
    return ClosConfig(nodes_per_leaf=1, n_spines=n_spines,
                      port_bandwidth=4, port_delay=1, queue_capacity=48,
                      spine_delay=tuple(1 + 4 * i for i in range(n_spines)),
                      loss_prob=loss, seed=seed, path_mode="spray")


def _check_delivery(res):
    for i, data in enumerate(res.payloads):
        np.testing.assert_array_equal(
            res.receiver._qp_buffer[i + 1][1][:len(data)], data,
            err_msg=f"sender {i}")
        assert res.receiver.check_completed(i + 1) == \
            res.senders[i].expected_completions(len(data))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31), st.sampled_from([0.0, 0.02]),
       st.sampled_from([2, 3]))
def test_spray_schedule_delivers_and_sr_retransmits_less(seed, loss,
                                                         n_spines):
    """Property: under a random spray/loss schedule (asymmetric spine
    delays => reorder), both RX modes deliver every byte bit-identically
    in both engines, and selective repeat retransmits no more than
    go-back-N on the same schedule."""
    retx = {}
    for mode in ("go_back_n", "selective_repeat"):
        stats = {}
        for engine in ("batched", "scan"):
            res = clos_incast_scenario(
                2, message_bytes=6 * 4096,
                clos_cfg=_clos_cfg(n_spines, loss, seed % 1000),
                rx_mode=mode, path_select="spray", engine=engine,
                max_ticks=80_000)
            _check_delivery(res)
            stats[engine] = (res.ticks,
                             [s.retx.retransmissions for s in res.senders],
                             res.receiver.stats)
        assert stats["batched"] == stats["scan"], \
            f"engine divergence in {mode}: {stats}"
        retx[mode] = sum(stats["batched"][1])
    assert retx["selective_repeat"] <= retx["go_back_n"]
    if loss == 0.0:
        # loss-free reorder: every go-back-N resend was spurious;
        # selective repeat must not produce ANY
        assert retx["selective_repeat"] == 0


def test_spine_failure_recovers_over_survivors():
    """Kill one spine plane mid-transfer: in-flight packets on it are
    lost, the transport re-sends over the survivors, every byte lands."""
    res = clos_incast_scenario(
        3, message_bytes=8 * 4096, rx_mode="selective_repeat",
        path_select="spray", fail_spine_at=12, fail_spine=1,
        max_ticks=80_000)
    _check_delivery(res)
    assert res.fabric.alive_paths == (0,)
    assert res.fabric.failure_dropped > 0
    # everything after the failure rode the surviving spine
    post = res.fabric.spine_pkts
    assert post[0] > 0


def test_ecmp_keeps_flows_on_one_spine():
    """ECMP mode: a flow's payload packets all hash onto one spine (no
    reorder), and the mapping is stable across packets."""
    cfg = dataclasses.replace(_clos_cfg(4, 0.0, 3), path_mode="ecmp")
    fab = ClosFabric(3, cfg)
    a = RdmaNode(1, fab, fc_window=16, path_select="ecmp")
    b = RdmaNode(0, fab, fc_window=16, path_select="ecmp")
    seen = {}
    orig_send = fab.send

    def snoop(src, dst, p):
        if p.opcode in pk.PAYLOAD_OPS:
            seen.setdefault(p.qpn, set()).add(p.path_id)
        orig_send(src, dst, p)

    fab.send = snoop
    rng = np.random.default_rng(11)
    qps = [a.init_rdma(1 << 16, b)[0] for _ in range(3)]
    for q in qps:
        a.rdma_write(q, rng.integers(0, 256, 5 * 4096, dtype=np.uint8))
    run_network([b, a], max_ticks=40_000)
    assert seen and all(len(s) == 1 for s in seen.values())


def test_spray_path_hashing_deterministic():
    """Repeat-twice determinism: the same seeded scenario routes the
    same packets over the same spines and lands the same stats."""
    def run():
        res = clos_incast_scenario(
            3, message_bytes=6 * 4096, clos_cfg=_clos_cfg(3, 0.02, 17),
            rx_mode="selective_repeat", path_select="spray",
            max_ticks=80_000)
        return (res.ticks, list(res.fabric.spine_pkts),
                res.fabric.total_tail_dropped,
                [s.stats.tx_pkts for s in res.senders],
                [s.retx.retransmissions for s in res.senders],
                res.receiver.stats)

    assert run() == run()


def test_sr_rejects_oversized_fc_window():
    """The sender-side burst bound must fit the RX bitmap."""
    fab = ClosFabric(2, ClosConfig())
    with pytest.raises(ValueError):
        RdmaNode(0, fab, rx_mode="selective_repeat",
                 fc_window=pipe.SR_WINDOW + 1)
    with pytest.raises(ValueError):
        RdmaNode(0, fab, path_select="zigzag")


# ---------------------------------------------------------------------------
# Link.reorder_prob: adjacent-swap reorder on the point-to-point model
# ---------------------------------------------------------------------------

def _run_reorder(engine, rx_mode):
    net = Network(2, LinkConfig(reorder_prob=0.35, latency_ticks=2,
                                seed=29))
    a = RdmaNode(0, net, engine=engine, fc_window=16, rx_mode=rx_mode)
    b = RdmaNode(1, net, engine=engine, fc_window=16, rx_mode=rx_mode)
    qpn = a.init_rdma(1 << 16, b)[0]
    data = np.random.default_rng(23).integers(0, 256, 40_000,
                                              dtype=np.uint8)
    a.rdma_write(qpn, data)
    run_network([a, b], max_ticks=80_000)
    np.testing.assert_array_equal(b._qp_buffer[qpn][1][:len(data)], data)
    return a.retx.retransmissions, b.stats


def test_link_reorder_heavy_both_modes():
    """Heavy adjacent-swap reorder on a lossless link: both RX modes
    deliver every byte, engines bit-identical; go-back-N visibly
    suffers (NAKs fire) while selective repeat absorbs the reorder
    without a single retransmission."""
    for rx_mode in ("go_back_n", "selective_repeat"):
        retx_b, stats_b = _run_reorder("batched", rx_mode)
        retx_s, stats_s = _run_reorder("scan", rx_mode)
        assert (retx_b, stats_b) == (retx_s, stats_s), rx_mode
        if rx_mode == "go_back_n":
            # the reorder is genuinely exercised: out-of-order NAKs fired
            assert stats_b.ooo_nak > 0
            gbn_retx = retx_b
        else:
            assert retx_b == 0           # nothing was lost — only reordered
            assert stats_b.ooo_nak == 0
    assert gbn_retx > 0
