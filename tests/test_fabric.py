"""Switched-fabric model, incast congestion, and batched-engine
equivalence tests.

Covers the PR's two acceptance properties:
  * the batched multi-QP RX/TX engines are bit-identical to the
    per-packet scan oracle — both at the pipeline level on randomized
    multi-QP traces and end-to-end on lossy-fabric simulations;
  * the fabric recovers exactly-once in-order delivery under drop-tail
    congestion (incast) and random wire loss with concurrent QPs.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.netsim import (FabricConfig, LinkConfig, Network,
                               SwitchedFabric, incast_scenario)
from repro.core.rdma import RdmaNode, run_network


# ---------------------------------------------------------------------------
# Fabric mechanics
# ---------------------------------------------------------------------------

def _pkt(i=0):
    return pk.Packet(opcode=pk.WRITE_ONLY, qpn=1, psn=i,
                     payload=np.zeros(8, np.uint8))


def test_fabric_delay_and_bandwidth():
    fab = SwitchedFabric(2, FabricConfig(port_bandwidth=2, port_delay=3,
                                         queue_capacity=16))
    for i in range(5):
        fab.send(0, 1, _pkt(i))
    got = []
    for tick in range(1, 10):
        out = fab.tick()
        for (_, dst), pkts in out.items():
            assert dst == 1
            got.append((tick, len(pkts)))
    # wire delay 3: nothing before tick 3; drain rate 2/tick afterwards
    assert got == [(3, 2), (4, 2), (5, 1)]
    assert fab.quiescent()
    assert fab.port_stats[1].delivered == 5


def test_fabric_per_port_config():
    fab = SwitchedFabric(3, FabricConfig(port_bandwidth=[1, 2, 8],
                                         port_delay=[1, 1, 5]))
    assert fab.bandwidth == [1, 2, 8]
    assert fab.delay == [1, 1, 5]
    with pytest.raises(ValueError):
        SwitchedFabric(2, FabricConfig(port_bandwidth=[1, 2, 3]))


def test_fabric_drop_tail():
    fab = SwitchedFabric(2, FabricConfig(port_bandwidth=1, port_delay=1,
                                         queue_capacity=4))
    for i in range(12):
        fab.send(0, 1, _pkt(i))
    delivered = 0
    for _ in range(40):
        for pkts in fab.tick().values():
            delivered += len(pkts)
    st_ = fab.port_stats[1]
    assert st_.tail_dropped == 12 - 4      # all arrive same tick; 4 fit
    assert delivered == 4
    assert st_.max_depth == 4
    assert fab.quiescent()


# ---------------------------------------------------------------------------
# Batched engine == scan oracle (pipeline level)
# ---------------------------------------------------------------------------

def _random_trace(rng, n_qps, n_pkts):
    """A randomized multi-QP header trace with in-seq / dup / gap mix."""
    pkts, psn = [], {}
    for _ in range(n_pkts):
        q = int(rng.integers(0, n_qps))
        p0 = psn.get(q, 0)
        r = rng.random()
        if r < 0.6:
            use, psn[q] = p0, p0 + 1                 # in sequence
        elif r < 0.8:
            use = max(0, p0 - int(rng.integers(1, 3)))   # duplicate
        else:
            use = p0 + int(rng.integers(1, 3))           # gap -> NAK
        plen = int(rng.integers(1, 200))
        op = int(rng.choice([pk.WRITE_ONLY, pk.WRITE_FIRST,
                             pk.WRITE_MIDDLE, pk.WRITE_LAST]))
        pkts.append(pk.Packet(opcode=op, qpn=q, psn=use,
                              payload=np.zeros(plen, np.uint8),
                              vaddr=int(rng.integers(0, 4096)),
                              dma_len=plen))
    return pkts


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 32), st.integers(1, 120),
       st.integers(0, 8))
def test_rx_batched_bit_identical_to_scan(seed, n_qps, n_pkts, pad):
    rng = np.random.default_rng(seed)
    b = pk.batch_from_packets(_random_trace(rng, n_qps, n_pkts), mtu=256)
    if pad:                                # trailing invalid lanes
        for k, v in b.items():
            b[k] = np.concatenate([v, np.zeros((pad,) + v.shape[1:],
                                               v.dtype)])
        b["valid"][n_pkts:] = 0
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    t0 = pipe.make_rx_tables(n_qps, initial_credits=5)
    # engines donate their tables arg — clone so both see the same t0
    ta, ra = pipe.rx_pipeline(pipe.clone_tables(t0), batch)
    tb, rb = pipe.rx_pipeline_batched(t0, batch)
    for f in pipe.RxTables._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ta, f)), np.asarray(getattr(tb, f)),
            err_msg=f"tables.{f}")
    for f in pipe.RxResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, f))[:n_pkts],
            np.asarray(getattr(rb, f))[:n_pkts], err_msg=f"result.{f}")


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 16), st.integers(1, 60))
def test_tx_batched_bit_identical_to_scan(seed, n_qps, n_cmds):
    rng = np.random.default_rng(seed)
    cmds = {"qpn": jnp.asarray(rng.integers(0, n_qps, n_cmds), jnp.int32),
            "n_pkts": jnp.asarray(rng.integers(1, 9, n_cmds), jnp.int32)}
    t0 = pipe.make_tx_tables(n_qps)
    ta, oa = pipe.tx_pipeline(pipe.clone_tables(t0), cmds)
    tb, ob = pipe.tx_pipeline_batched(t0, cmds)
    np.testing.assert_array_equal(np.asarray(oa["start_psn"]),
                                  np.asarray(ob["start_psn"]))
    np.testing.assert_array_equal(np.asarray(ta.npsn), np.asarray(tb.npsn))
    np.testing.assert_array_equal(np.asarray(ta.msn), np.asarray(tb.msn))


# ---------------------------------------------------------------------------
# Batched engine == scan oracle (end-to-end on a lossy trace)
# ---------------------------------------------------------------------------

def _run_lossy_multiqp(engine: str):
    net = Network(2, LinkConfig(loss_prob=0.08, reorder_prob=0.03,
                                latency_ticks=2, seed=21))
    a = RdmaNode(0, net, engine=engine)
    b = RdmaNode(1, net, engine=engine)
    qps = [a.init_rdma(1 << 16, b)[0] for _ in range(3)]
    rng = np.random.default_rng(17)
    datas = [rng.integers(0, 256, 20_000 + 991 * i, dtype=np.uint8)
             for i in range(3)]
    for q, d in zip(qps, datas):
        a.rdma_write(q, d)
    run_network([a, b], max_ticks=60_000)
    bufs = [b._qp_buffer[i + 1][1][:len(d)].copy()
            for i, d in enumerate(datas)]
    return bufs, datas, b.stats, b.rx_tables


def test_engines_identical_end_to_end():
    """Same lossy trace, both engines: identical delivery, stats and
    final RX tables (the PR's bit-identity acceptance criterion)."""
    bufs_s, datas, stats_s, tbl_s = _run_lossy_multiqp("scan")
    bufs_b, _, stats_b, tbl_b = _run_lossy_multiqp("batched")
    for bs, bb, d in zip(bufs_s, bufs_b, datas):
        np.testing.assert_array_equal(bs, d)
        np.testing.assert_array_equal(bb, d)
    assert stats_s == stats_b
    for f in pipe.RxTables._fields:
        np.testing.assert_array_equal(np.asarray(getattr(tbl_s, f)),
                                      np.asarray(getattr(tbl_b, f)),
                                      err_msg=f"rx_tables.{f}")


def test_unknown_engine_rejected():
    net = Network(2, LinkConfig())
    with pytest.raises(ValueError):
        RdmaNode(0, net, engine="warp")


# ---------------------------------------------------------------------------
# Reliability over the fabric (satellite: retransmission path under the
# new fabric model — exactly-once in-order delivery, >= 2 concurrent QPs)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31), st.sampled_from([0.0, 0.05, 0.15]),
       st.integers(2, 4))
def test_fabric_lossy_exactly_once_multi_qp(seed, loss, n_qps):
    """Property: random wire loss + shallow egress queues, >=2 concurrent
    QPs — every byte lands exactly once, in order."""
    fab = SwitchedFabric(2, FabricConfig(
        port_bandwidth=8, port_delay=2, queue_capacity=48,
        loss_prob=loss, seed=seed % 1000))
    a = RdmaNode(0, fab, fc_window=16)
    b = RdmaNode(1, fab, fc_window=16)
    rng = np.random.default_rng(seed)
    qps = [a.init_rdma(1 << 17, b)[0] for _ in range(n_qps)]
    datas = [rng.integers(0, 256, int(rng.integers(5_000, 40_000)),
                          dtype=np.uint8) for _ in range(n_qps)]
    for q, d in zip(qps, datas):
        a.rdma_write(q, d)
    run_network([a, b], max_ticks=200_000)
    n_frag = 0
    for i, d in enumerate(datas):
        np.testing.assert_array_equal(b._qp_buffer[i + 1][1][:len(d)], d,
                                      err_msg=f"qp {i + 1}")
        n_frag += pk.read_resp_npkts(len(d))
    # exactly-once: every unique fragment DMA'd exactly once
    assert b.stats.accepted == n_frag
    assert not a.retx.exhausted and not b.retx.exhausted


def test_incast_congestion_recovers():
    """8-to-1 incast through a shallow-buffered port: drop-tail losses
    actually occur and the transport recovers every byte exactly once."""
    res = incast_scenario(
        8, message_bytes=32768,
        fabric_cfg=FabricConfig(port_bandwidth=4, port_delay=2,
                                queue_capacity=24, seed=7))
    recv = res.receiver
    total_frag = 0
    for i, data in enumerate(res.payloads):
        np.testing.assert_array_equal(
            recv._qp_buffer[i + 1][1][:len(data)], data,
            err_msg=f"sender {i}")
        total_frag += pk.read_resp_npkts(len(data))
    assert recv.stats.accepted == total_frag
    # congestion genuinely happened and was repaired
    assert res.fabric.port_stats[0].tail_dropped > 0
    assert sum(s.stats.retransmissions for s in res.senders) > 0
    assert not recv.retx.exhausted
    assert all(not s.retx.exhausted for s in res.senders)
