"""Per-arch smoke + numerical equivalence tests for the model substrate.

Each assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU (shapes + finiteness), plus the strongest
functional check we have: prefill+decode must reproduce the full-forward
logits exactly (fp32).  Component-level equivalences (chunked-vs-naive
attention, mLSTM chunkwise-vs-step, RG-LRU scan-vs-step) pin the
optimized paths to their simple forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moe_mod
from repro.common.config import ModelConfig
from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models import ssm
from repro.models.attention import _dot_attention
from repro.models.model import Model

B, S = 2, 24


def _batch_for(cfg, b, s, key=0, with_targets=True):
    toks = jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if with_targets:
        batch["targets"] = jnp.roll(toks, -1, axis=1)
    if cfg.is_encdec:
        batch["audio_embed"] = jax.random.normal(
            jax.random.key(key + 1), (b, 16, cfg.d_model), jnp.float32)
    if cfg.vision_stub:
        batch["vision_embed"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)
        batch["vision_mask"] = jnp.zeros((b, s), jnp.int32)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init_params(jax.random.key(0))
    batch = _batch_for(cfg, B, S)
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    logits, _, _, _ = m.forward(params, batch, train=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one grad step is finite
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_decode_matches_full_forward(arch, monkeypatch):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    if cfg.n_experts:
        # avoid capacity-drop mismatches between batched and single-step
        # routing (dropping semantics tested separately below)
        monkeypatch.setattr(moe_mod, "CAPACITY_FACTOR", 8.0)
    m = Model(cfg)
    params = m.init_params(jax.random.key(0))
    full = _batch_for(cfg, B, S + 1)
    logits_full, _, _, _ = m.forward(params, full, train=False)
    pre = {k: (v[:, :S] if (hasattr(v, "ndim") and v.ndim >= 2
                            and v.shape[1] == S + 1) else
               (v[:, :, :S] if hasattr(v, "ndim") and v.ndim == 3
                and v.shape[-1] == S + 1 else v))
           for k, v in full.items() if k != "targets"}
    cache = m.init_cache(jax.random.key(1), B, S + 8,
                         enc_len=(16 if cfg.is_encdec else 0))
    _, cache = m.prefill(params, pre, cache)
    lg, _ = m.decode_step(params, cache, full["tokens"][:, S:S + 1],
                          jnp.asarray(S, jnp.int32))
    err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, S])))
    assert err < 2e-3, f"{arch}: decode diverges from forward by {err}"


def test_chunked_attention_matches_naive():
    b, s, h, kv, d = 2, 256, 4, 2, 16
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (b, s, h, d), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, d), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mask = (pos[:, None, :] <= pos[:, :, None])[:, None, None]
    naive = _dot_attention(q, k, v, mask, 0.25, 0.0, "naive")
    chunk = _dot_attention(q, k, v, mask, 0.25, 0.0, "chunked", 64)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_with_softcap_and_window():
    b, s, h, kv, d = 1, 128, 2, 2, 8
    keys = jax.random.split(jax.random.key(1), 3)
    q, k, v = (jax.random.normal(kk, (b, s, hh, d), jnp.float32)
               for kk, hh in zip(keys, (h, kv, kv)))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    causal = pos[:, None, :] <= pos[:, :, None]
    window = pos[:, None, :] > pos[:, :, None] - 32
    mask = (causal & window)[:, None, None]
    naive = _dot_attention(q, k, v, mask, 0.35, 50.0, "naive")
    chunk = _dot_attention(q, k, v, mask, 0.35, 50.0, "chunked", 32)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_mlstm_chunkwise_matches_stepwise():
    b, h, s, dh = 2, 2, 64, 8
    keys = jax.random.split(jax.random.key(2), 5)
    q, k, v = (jax.random.normal(kk, (b, h, s, dh), jnp.float32)
               for kk in keys[:3])
    ig = jax.random.normal(keys[3], (b, h, s), jnp.float32)
    fg = jax.random.normal(keys[4], (b, h, s), jnp.float32) + 2.0
    hc, state_c = ssm._mlstm_chunkwise(q, k, v, ig, fg, chunk=16)
    # stepwise reference
    state = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
             jnp.full((b, h), -1e30))
    outs = []
    for t in range(s):
        o, state = ssm._mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                   ig[:, :, t], fg[:, :, t], state)
        outs.append(o)
    hs = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_c[0]), np.asarray(state[0]),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    b, s, d = 2, 37, 16
    keys = jax.random.split(jax.random.key(3), 2)
    a = jax.nn.sigmoid(jax.random.normal(keys[0], (b, s, d))) * 0.98
    bb = jax.random.normal(keys[1], (b, s, d))
    h_scan = ssm._rglru_scan(a, bb)
    h = jnp.zeros((b, d))
    outs = []
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        outs.append(h)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)


def test_moe_matches_dense_reference():
    """With ample capacity, the sort-based dispatch equals the dense
    weighted-sum-over-selected-experts computation."""
    cfg = get_smoke_config("deepseek-v2-236b").replace(
        compute_dtype="float32")
    import repro.models.moe as moe
    old_cf = moe.CAPACITY_FACTOR
    moe.CAPACITY_FACTOR = 8.0
    try:
        from repro.models.moe import moe_spec, moe_ffn, route
        from repro.models import params as P
        spec = moe_spec(cfg)
        p = P.init(spec, jax.random.key(0), "float32")
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                              jnp.float32) * 0.1
        y, aux, load = moe_ffn(cfg, p, x, jnp.float32)
        # dense reference
        ids, w, _, _ = route(cfg, p, x)
        w1, w3, w2 = p["w1"], p["w3"], p["w2"]
        h1 = jnp.einsum("bsd,edf->bsef", x, w1)
        h3 = jnp.einsum("bsd,edf->bsef", x, w3)
        ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h1) * h3, w2)
        sel = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # (b,s,k,e)
        wk = jnp.einsum("bske,bsk->bse", sel, w)
        ref = jnp.einsum("bsed,bse->bsd", ye, wk)
        from repro.models.layers import ffn
        ref = ref + ffn(p["shared"], x, jnp.float32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)
    finally:
        moe.CAPACITY_FACTOR = old_cf


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must keep exactly the last
    ``window`` positions."""
    cfg = get_smoke_config("gemma3-4b").replace(compute_dtype="float32")
    m = Model(cfg)
    params = m.init_params(jax.random.key(0))
    total = 40                      # window is 16
    toks = jax.random.randint(jax.random.key(9), (1, total), 0, cfg.vocab)
    logits_full, _, _, _ = m.forward(
        params, {"tokens": toks, "targets": jnp.zeros_like(toks)},
        train=False)
    cache = m.init_cache(jax.random.key(1), 1, total)
    _, cache = m.prefill(params, {"tokens": toks[:, :16]}, cache)
    for t in range(16, total):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t, jnp.int32))
    err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, total - 1])))
    assert err < 2e-3, f"ring cache diverged: {err}"
