"""Tests for the §Perf optimization knobs: numerical equivalence of the
optimized paths against the paper-faithful baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.attention import (_build_mask, _dot_attention,
                                    _sliding_attention_blocked)
from repro.models.model import Model


def test_blocked_sliding_attention_equals_naive():
    b, s, h, kv, d, w = 2, 384, 4, 2, 16, 96
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, kv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mask = _build_mask(pos, pos, True, w)[:, None, None]
    ref = _dot_attention(q, k, v, mask, 0.25, 30.0, "naive")
    blk = _sliding_attention_blocked(q, k, v, pos, w, 0.25, 30.0, block_q=96)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_blocked_model_forward_equals_naive():
    cfg = get_smoke_config("gemma2-27b").replace(compute_dtype="float32")
    m_naive = Model(cfg)
    m_blk = Model(cfg.replace(attn_impl="blocked"))
    params = m_naive.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 48), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    l1, _, _, _ = m_naive.forward(params, batch, train=False)
    l2, _, _, _ = m_blk.forward(params, batch, train=False)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_decode_tracks_fp():
    cfg = get_smoke_config("gemma2-2b").replace(compute_dtype="float32")
    cfg_q = cfg.replace(kv_cache_quant=True)
    toks = jax.random.randint(jax.random.key(1), (2, 25), 0, cfg.vocab)
    outs = {}
    for name, c in (("fp", cfg), ("int8", cfg_q)):
        m = Model(c)
        params = m.init_params(jax.random.key(0))
        cache = m.init_cache(jax.random.key(0), 2, 32)
        _, cache = m.prefill(params, {"tokens": toks[:, :24]}, cache)
        lg, _ = m.decode_step(params, cache, toks[:, 24:25],
                              jnp.asarray(24, jnp.int32))
        outs[name] = np.asarray(lg, np.float32)
    err = np.abs(outs["fp"] - outs["int8"]).max()
    assert err < 0.05, f"int8 KV cache drifted: {err}"
    # and the quantized cache really is int8
    m = Model(cfg_q)
    cache = m.init_cache(jax.random.key(0), 2, 32)
    leaves = jax.tree.leaves(cache)
    assert any(l.dtype == jnp.int8 for l in leaves)


def test_fused_chain_kernel_matches_two_pass():
    from repro.kernels.fused_chain import (fused_decrypt_dpi_pallas,
                                           fused_decrypt_dpi_ref)
    from repro.kernels.ref import expand_key
    from repro.kernels.dpi_mlp import init_dpi_params, ternarize
    rng = np.random.default_rng(0)
    pay = rng.integers(0, 256, (5, 1024), dtype=np.uint8)
    rk = expand_key(rng.integers(0, 256, 16, dtype=np.uint8))
    params = ternarize(init_dpi_params(jax.random.key(0)))
    p1, s1 = fused_decrypt_dpi_pallas(jnp.asarray(pay), rk, params)
    p2, s2 = fused_decrypt_dpi_ref(jnp.asarray(pay), rk, params)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_ep2d_moe_numerics_match_ep_tp():
    """Both expert layouts compute the same function."""
    import repro.models.moe as moe
    from repro.models import params as P
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        compute_dtype="float32")
    spec = moe.moe_spec(cfg)           # ep_tp spec (same param shapes)
    p = P.init(spec, jax.random.key(0), "float32")
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.1
    y1, _, _ = moe.moe_ffn(cfg, p, x, jnp.float32)
    y2, _, _ = moe.moe_ffn(cfg.replace(expert_sharding="ep2d"), p, x,
                           jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_ep_sm_shardmap_moe_matches_pjit_on_real_mesh():
    """The §Perf Cell-1 fix: shard_map MoE must equal the pjit MoE on a
    real multi-device mesh (collectives actually execute).  Needs its own
    process: 8 host devices must be configured before jax init."""
    import os
    import subprocess
    import sys
    snippet = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import repro.models.moe as moe
from repro.models import params as P
from repro.parallel import sharding as sh
from repro.configs import get_smoke_config
cfg = get_smoke_config('deepseek-v3-671b').replace(compute_dtype='float32')
mesh = jax.make_mesh((4, 2), ("data", "model"))
p = P.init(moe.moe_spec(cfg), jax.random.key(0), "float32")
x = jax.random.normal(jax.random.key(1), (4, 4096, cfg.d_model)) * 0.1
with sh.activate(mesh, sh.make_rules("train"), "t"):
    y_tp = jax.jit(lambda p, x: moe.moe_ffn(cfg, p, x, jnp.float32)[0])(p, x)
    csm = cfg.replace(expert_sharding="ep_sm")
    y_sm = jax.jit(lambda p, x: moe.moe_ffn(csm, p, x, jnp.float32)[0])(p, x)
err = float(jnp.max(jnp.abs(y_tp - y_sm)))
assert err < 1e-5, err
print("EP_SM_OK", err)
"""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run([sys.executable, "-c", snippet],
                          capture_output=True, text=True, timeout=560,
                          env=env, cwd=root)
    assert "EP_SM_OK" in proc.stdout, proc.stderr[-800:]
