"""balint (repro.analysis) — the checker gets checked.

Every determinism rule has a positive fixture (known violations that
MUST be found) and negative cases (clean idioms that must NOT be);
suppression comments and baseline add/expire semantics are exercised
end to end; the jaxpr pass is pinned against the live engines; and the
runtime host-sync census must agree between the scan oracle and the
batched engine (engine choice is in-graph — it cannot change how often
the host is crossed).
"""
from __future__ import annotations

import pathlib

import pytest

from repro.analysis import run_analysis
from repro.analysis import determinism, protocol, purity
from repro.analysis.report import Report, render_json, render_text
from repro.analysis.violations import (Baseline, Violation,
                                       apply_suppressions)

FIXTURES = pathlib.Path(__file__).resolve().parent / "balint_fixtures"


def _rules_in(path) -> set:
    return {v.rule for v in determinism.run([path])}


# ---------------------------------------------------------------------------
# determinism rules: positives and negatives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,rule,count", [
    ("bad_wall_clock.py", "wall-clock", 4),
    ("bad_rng.py", "unseeded-rng", 3),
    ("bad_set_iter.py", "set-iteration", 3),
    ("bad_dict_order.py", "dict-order", 1),
    ("bad_mutable_default.py", "mutable-default", 3),
])
def test_rule_positive(fixture, rule, count):
    found = [v for v in determinism.run([FIXTURES / fixture])
             if v.rule == rule]
    assert len(found) == count, \
        f"{fixture}: expected {count} {rule} violations, got " \
        f"{[(v.line, v.message) for v in found]}"


def test_rules_do_not_cross_fire():
    """Each bad_* fixture trips exactly its own rule."""
    assert _rules_in(FIXTURES / "bad_wall_clock.py") == {"wall-clock"}
    assert _rules_in(FIXTURES / "bad_mutable_default.py") == \
        {"mutable-default"}


def test_clean_fixture_is_clean():
    assert determinism.run([FIXTURES / "good_clean.py"]) == []


def test_dict_order_negatives():
    """sorted() iteration and non-wire iteration must not fire."""
    vs = [v for v in determinism.run([FIXTURES / "bad_dict_order.py"])
          if v.rule == "dict-order"]
    assert len(vs) == 1
    assert "flush" not in vs[0].message or vs[0].line < 15, \
        "only the unsorted wire loop may fire"


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_comments():
    raw = determinism.run([FIXTURES / "suppressed.py"])
    # the violations exist pre-suppression...
    assert {v.rule for v in raw} == {"wall-clock", "mutable-default"}
    # ...and the disable comments hide all of them
    assert apply_suppressions(raw) == []


def test_suppression_is_rule_scoped():
    """A disable for one rule must not hide another rule's finding on
    the same line."""
    v = Violation("unseeded-rng", "tests/balint_fixtures/suppressed.py",
                  7, "synthetic")
    assert apply_suppressions([v]) == [v]


# ---------------------------------------------------------------------------
# baseline add / expire
# ---------------------------------------------------------------------------

def test_baseline_partition_and_expiry():
    v_live = Violation("wall-clock", "a.py", 3, "wall-clock read")
    v_new = Violation("dict-order", "b.py", 9, "unsorted send loop")
    baseline = Baseline([
        {"rule": "wall-clock", "path": "a.py",
         "message": "wall-clock read", "reason": "deliberate"},
        {"rule": "set-iteration", "path": "gone.py",
         "message": "iteration over a set", "reason": "was deliberate"},
    ])
    active, baselined, expired = baseline.partition([v_live, v_new])
    assert active == [v_new]                 # new debt surfaces
    assert baselined == [v_live]             # known debt is absorbed
    assert [e["path"] for e in expired] == ["gone.py"]   # stale entry
    report = Report(active, baselined, expired, ["determinism"])
    assert not report.strict_ok              # expired entries fail strict


def test_baseline_line_churn_immune():
    """Fingerprints ignore line numbers: moving a violation within its
    file must not expire the baseline entry."""
    v = Violation("wall-clock", "a.py", 99, "wall-clock read")
    baseline = Baseline([{"rule": "wall-clock", "path": "a.py",
                          "message": "wall-clock read", "reason": "x"}])
    active, baselined, expired = baseline.partition([v])
    assert (active, baselined, expired) == ([], [v], [])


def test_fixture_dir_fails_strict():
    """Acceptance: seeded fixture violations fail a --strict run."""
    report = run_analysis(paths=[FIXTURES], passes=["determinism"],
                          baseline_path=None)
    assert not report.strict_ok
    assert len(report.violations) >= 10


# ---------------------------------------------------------------------------
# jaxpr pass pins against the live engines
# ---------------------------------------------------------------------------

ENGINE_ENTRIES = ["rx_pipeline[gbn]", "rx_pipeline[sr]",
                  "rx_pipeline_batched[gbn]", "rx_pipeline_batched[sr]",
                  "tx_pipeline", "tx_pipeline_batched"]


def test_engines_trace_pure():
    """Both engines, both rx_modes: no host callbacks, no f64, no
    concretization — and since the fused epoch core landed (ROADMAP
    item 2), no missing-donation either: every engine entry point
    donates its carried table state, so the six baselined debt entries
    are retired and the registry must come back empty."""
    vs = purity.run(names=ENGINE_ENTRIES)
    assert vs == [], [f"{v.rule}: {v.message}" for v in vs]


def test_protocol_pass_clean():
    assert protocol.run() == []


def test_repo_is_strict_clean():
    """Acceptance: the checked-in tree passes --strict (AST + protocol
    passes; the jaxpr pass is pinned separately above)."""
    report = run_analysis(passes=["determinism", "protocol"])
    assert report.strict_ok, render_text(report)


# ---------------------------------------------------------------------------
# host-sync census: scan vs batched engines
# ---------------------------------------------------------------------------

def test_census_scan_vs_batched_identical():
    """Engine choice is in-graph: the scan oracle and the batched
    engine must cross the host boundary identically often (PR 8's
    counter contract — counters ride carried state, no extra syncs)."""
    from repro.analysis.census import census_fig6
    scan = census_fig6(n_senders=2, message_bytes=8192, engine="scan")
    batched = census_fig6(n_senders=2, message_bytes=8192,
                          engine="batched")
    assert scan == batched
    assert scan["d2h"] > 0 and scan["h2d"] > 0   # instrument sees traffic


def test_census_deterministic():
    from repro.analysis.census import census_fig6
    a = census_fig6(n_senders=2, message_bytes=8192)
    b = census_fig6(n_senders=2, message_bytes=8192)
    assert a == b


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------

def test_reporters_render():
    v = Violation("wall-clock", "a.py", 3, "wall-clock read `time.time()`")
    r = Report([v], [], [{"rule": "dict-order", "path": "b.py",
                          "message": "gone", "reason": "was deliberate"}],
               ["determinism"])
    text = render_text(r)
    assert "a.py:3" in text and "EXPIRED" in text and "FAIL" in text
    import json
    doc = json.loads(render_json(r))
    assert doc["strict_ok"] is False
    assert doc["violations"][0]["rule"] == "wall-clock"
