"""Paper Fig. 8 + §6.4.2 analogue: deep packet inspection.

(1) Detection quality of the ternary MLP: whole-payload executables
    (paper: 97.83%) and partially embedded executables (paper: 89.35%),
    vs. benign false positives.
(2) Datapath cost: throughput/latency of the service chain with and
    without the DPI model attached (paper: no measurable impact — the
    parallel path hides it; we report the measured delta)."""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import telemetry as tm
from repro.core.services import AesService, DpiService, ServiceChain
from repro.data.dpi_dataset import make_dataset, payload_with_embedded_malware
from repro.kernels.dpi_mlp import train_dpi_params


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small dataset + short training (CI bench job)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    args = ap.parse_args(argv)

    x, y = make_dataset(512 if args.smoke else 4096, seed=0)
    params = train_dpi_params(x, y, steps=60 if args.smoke else 300)
    dpi = DpiService(params=params)
    rng = np.random.default_rng(1)
    n = 64 if args.smoke else 256
    full = np.stack([payload_with_embedded_malware(4096, 1.0, rng)
                     for _ in range(n)])
    part = np.stack([payload_with_embedded_malware(4096, 0.15, rng)
                     for _ in range(n)])
    ben = np.stack([payload_with_embedded_malware(4096, 0.0, rng)
                    for _ in range(n)])
    plen = jnp.asarray(np.full(n, 4096, np.int32))
    det_full = float(np.asarray(dpi(jnp.asarray(full), plen)).mean())
    det_part = float(np.asarray(dpi(jnp.asarray(part), plen)).mean())
    fp = float(np.asarray(dpi(jnp.asarray(ben), plen)).mean())
    emit("fig8_dpi_detect_full", 0.0,
         f"rate={det_full:.4f};paper=0.9783")
    emit("fig8_dpi_detect_partial", 0.0,
         f"rate={det_part:.4f};paper=0.8935")
    emit("fig8_dpi_false_positive", 0.0, f"rate={fp:.4f}")

    # datapath cost with vs without DPI (on-path AES as the base chain)
    base = ServiceChain(on_path=[AesService(key=np.arange(16, dtype=np.uint8))])
    with_dpi = ServiceChain(
        on_path=[AesService(key=np.arange(16, dtype=np.uint8))],
        parallel=[dpi])
    payj = jnp.asarray(ben)
    us0 = time_fn(lambda: base.process(payj, plen), iters=5)
    us1 = time_fn(lambda: with_dpi.process(payj, plen), iters=5)
    emit("fig8_chain_without_dpi", us0, f"MBps={n*4096/us0:.1f}")
    emit("fig8_chain_with_dpi", us1,
         f"MBps={n*4096/us1:.1f};overhead={100*(us1-us0)/us0:.1f}%")

    reg = tm.MetricRegistry()
    reg.gauge("fig8/detect_full", det_full)
    reg.gauge("fig8/detect_partial", det_part)
    reg.gauge("fig8/false_positive", fp)
    reg.gauge("fig8/chain_overhead_pct", 100 * (us1 - us0) / us0)
    results = {"mode": "smoke" if args.smoke else "full",
               "detect_full": round(det_full, 4),
               "detect_partial": round(det_part, 4),
               "false_positive": round(fp, 4),
               "chain_without_dpi_us": round(us0, 1),
               "chain_with_dpi_us": round(us1, 1),
               "telemetry": reg.flat()}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
