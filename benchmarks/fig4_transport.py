"""Paper Fig. 4 analogue: RDMA WRITE/READ latency and throughput vs
buffer size over the switched-network simulator (BALBOA <-> BALBOA).

Latency: ticks for a single buffer transmission + completion polling.
Throughput: repeated batch transmissions of 64 buffers (paper protocol),
reported as protocol efficiency (payload packets / total packets) and
host-pipeline throughput (MB/s through the jitted RX pipeline + chain).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks._util import emit
from repro.core import packet as pk
from repro.core import telemetry as tm
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network

SIZES = (64, 1024, 4096, 32768, 262144, 1048576)
SMOKE_SIZES = (64, 4096, 32768)


def run_once(size: int, op: str = "write"):
    net = Network(2, LinkConfig(latency_ticks=3, seed=1))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, buf_a = a.init_rdma(max(size, 4096) * 2, b)
    data = np.random.default_rng(0).integers(0, 256, size, dtype=np.uint8)
    t0 = time.perf_counter()
    if op == "write":
        a.rdma_write(qpn_a, data)
        target, qpn_t = b, 1
    else:
        buf_a[:size] = data
        b.rdma_read(1, size)
        target, qpn_t = b, 1
    ticks = run_network([a, b], max_ticks=200_000)
    wall = time.perf_counter() - t0
    assert target.check_completed(qpn_t) >= 1
    return ticks, wall, a.stats.tx_pkts + b.stats.tx_pkts


def throughput(size: int, n_bufs: int = 64):
    net = Network(2, LinkConfig(latency_ticks=3, seed=2))
    a, b = RdmaNode(0, net, fc_window=256), RdmaNode(1, net, rx_credits=256)
    qpn_a, _, _ = a.init_rdma(max(size, 4096) * 2, b)
    data = np.random.default_rng(1).integers(0, 256, size, dtype=np.uint8)
    t0 = time.perf_counter()
    for _ in range(n_bufs):
        a.rdma_write(qpn_a, data)
        run_network([a, b], max_ticks=100_000)
    wall = time.perf_counter() - t0
    payload_pkts = pk.read_resp_npkts(size) * n_bufs
    eff = payload_pkts / max(a.stats.tx_pkts, 1)
    mbs = size * n_bufs / wall / 1e6
    return wall, eff, mbs


def telemetry_run(size: int = 32768) -> dict:
    """One fully instrumented WRITE: fabric + both nodes registered in
    a ``MetricRegistry``, flat snapshot embedded in the ``--json``
    output (what ``benchmarks/regress.py`` ingests)."""
    net = Network(2, LinkConfig(latency_ticks=3, seed=1))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, _ = a.init_rdma(max(size, 4096) * 2, b)
    reg, rec = tm.instrument(fabric=net, nodes=[a, b])
    data = np.random.default_rng(0).integers(0, 256, size, dtype=np.uint8)
    a.rdma_write(qpn_a, data)
    ticks = run_network([a, b], max_ticks=200_000)
    assert b.check_completed(1) >= 1
    snap = reg.snapshot()
    by = snap["flight"]["by_kind"]
    assert by.get("inject", 0) + by.get("wire_drop", 0) == \
        snap["fabric"]["injected"]
    return {"ticks": ticks, "bytes": size, "telemetry": reg.flat(snap)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes only (CI bench job)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    args = ap.parse_args(argv)

    sizes = SMOKE_SIZES if args.smoke else SIZES
    tput_sizes = (4096,) if args.smoke else (4096, 32768, 262144)
    n_bufs = 4 if args.smoke else 16
    results = {"mode": "smoke" if args.smoke else "full",
               "latency": {}, "throughput": {}}
    for size in sizes:
        ticks, wall, _ = run_once(size, "write")
        emit(f"fig4_write_latency_{size}B", wall * 1e6,
             f"ticks={ticks}")
        results["latency"][str(size)] = {"op": "write", "ticks": ticks,
                                         "wall_us": round(wall * 1e6, 1)}
        ticks, wall, _ = run_once(size, "read")
        emit(f"fig4_read_latency_{size}B", wall * 1e6, f"ticks={ticks}")
        results["latency"][str(size)]["read_ticks"] = ticks
    for size in tput_sizes:
        wall, eff, mbs = throughput(size, n_bufs=n_bufs)
        emit(f"fig4_write_throughput_{size}B", wall * 1e6 / n_bufs,
             f"host_MBps={mbs:.1f};protocol_efficiency={eff:.3f}")
        results["throughput"][str(size)] = {
            "protocol_efficiency": round(eff, 4),
            "host_MBps": round(mbs, 1)}
    results["instrumented_write"] = telemetry_run(
        4096 if args.smoke else 32768)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
