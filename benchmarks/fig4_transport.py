"""Paper Fig. 4 analogue: RDMA WRITE/READ latency and throughput vs
buffer size over the switched-network simulator (BALBOA <-> BALBOA).

Latency: ticks for a single buffer transmission + completion polling.
Throughput: repeated batch transmissions of 64 buffers (paper protocol),
reported as protocol efficiency (payload packets / total packets) and
host-pipeline throughput (MB/s through the jitted RX pipeline + chain).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit
from repro.core import packet as pk
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network

SIZES = (64, 1024, 4096, 32768, 262144, 1048576)


def run_once(size: int, op: str = "write"):
    net = Network(2, LinkConfig(latency_ticks=3, seed=1))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qpn_a, _, buf_a = a.init_rdma(max(size, 4096) * 2, b)
    data = np.random.default_rng(0).integers(0, 256, size, dtype=np.uint8)
    t0 = time.perf_counter()
    if op == "write":
        a.rdma_write(qpn_a, data)
        target, qpn_t = b, 1
    else:
        buf_a[:size] = data
        b.rdma_read(1, size)
        target, qpn_t = b, 1
    ticks = run_network([a, b], max_ticks=200_000)
    wall = time.perf_counter() - t0
    assert target.check_completed(qpn_t) >= 1
    return ticks, wall, a.stats.tx_pkts + b.stats.tx_pkts


def throughput(size: int, n_bufs: int = 64):
    net = Network(2, LinkConfig(latency_ticks=3, seed=2))
    a, b = RdmaNode(0, net, fc_window=256), RdmaNode(1, net, rx_credits=256)
    qpn_a, _, _ = a.init_rdma(max(size, 4096) * 2, b)
    data = np.random.default_rng(1).integers(0, 256, size, dtype=np.uint8)
    t0 = time.perf_counter()
    for _ in range(n_bufs):
        a.rdma_write(qpn_a, data)
        run_network([a, b], max_ticks=100_000)
    wall = time.perf_counter() - t0
    payload_pkts = pk.read_resp_npkts(size) * n_bufs
    eff = payload_pkts / max(a.stats.tx_pkts, 1)
    mbs = size * n_bufs / wall / 1e6
    return wall, eff, mbs


def main():
    for size in SIZES:
        ticks, wall, _ = run_once(size, "write")
        emit(f"fig4_write_latency_{size}B", wall * 1e6,
             f"ticks={ticks}")
        ticks, wall, _ = run_once(size, "read")
        emit(f"fig4_read_latency_{size}B", wall * 1e6, f"ticks={ticks}")
    for size in (4096, 32768, 262144):
        wall, eff, mbs = throughput(size, n_bufs=16)
        emit(f"fig4_write_throughput_{size}B", wall * 1e6 / 16,
             f"host_MBps={mbs:.1f};protocol_efficiency={eff:.3f}")


if __name__ == "__main__":
    main()
