"""Perf-regression gate: diff fresh bench smoke runs against the
committed ``BENCH_*.json`` baselines and fail CI when a tick-based
metric regresses beyond tolerance.

Only *simulator-tick* metrics are compared (goodput per tick, ticks,
retransmissions, drops, overlap) — never wall-clock.  The simulator is
seeded and tick-deterministic, so these are stable across machines;
the tolerance only absorbs intentional-but-small drift and the
absolute slack keeps tiny counters (0 -> 1 retransmit) from flapping.

Usage (what the CI bench-smoke job runs):

    python -m benchmarks.regress \
        --pair fig6  BENCH_fig6_multipath.json  fig6_smoke.json \
        --pair fig10 BENCH_fig10_dlrm.json      fig10_smoke.json \
        --pair fig11 BENCH_fig11_allreduce.json fig11_smoke.json

Exit status 0 = no regression; 1 = at least one metric regressed (or a
baseline/fresh pair was unreadable / mode-mismatched).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

# metric value + direction: "higher" = bigger is better (goodput),
# "lower" = smaller is better (drops, retransmissions, ticks)
Metrics = Dict[str, Tuple[float, str]]


def extract_fig6(d: dict) -> Metrics:
    out: Metrics = {}
    for r in d.get("incast_cc", []):
        k = f"incast_cc/{r['fan_in']}to1/{r['cc']}"
        out[f"{k}/goodput_B_per_tick"] = (r["goodput_B_per_tick"], "higher")
        out[f"{k}/tail_dropped"] = (r["tail_dropped"], "lower")
        out[f"{k}/retransmissions"] = (r["retransmissions"], "lower")
    for r in d.get("multipath", []):
        k = (f"multipath/{r['fan_in']}to1/{r['rx_mode']}/"
             f"{r['path_select']}/fail{r['fail_spine_at']}")
        out[f"{k}/goodput_B_per_tick"] = (r["goodput_B_per_tick"], "higher")
        out[f"{k}/retransmissions"] = (r["retransmissions"], "lower")
        out[f"{k}/tail_dropped"] = (r["tail_dropped"], "lower")
    t = d.get("traced_incast")
    if t:
        out["traced_incast/ticks"] = (t["ticks"], "lower")
    return out


def extract_fig10(d: dict) -> Metrics:
    out: Metrics = {}
    ing = d.get("ingest", {})
    if "sync" in ing:
        out["sync/goodput_B_per_tick"] = (ing["sync"]["goodput"], "higher")
        out["sync/ticks"] = (ing["sync"]["ticks"], "lower")
    for r, s in ing.get("streamed", {}).items():
        out[f"streamed/{r}r/goodput_B_per_tick"] = (s["goodput"], "higher")
        out[f"streamed/{r}r/overlap"] = (s["overlap"], "higher")
        out[f"streamed/{r}r/ticks"] = (s["ticks"], "lower")
    if "speedup_4r" in ing:
        out["speedup_4r"] = (ing["speedup_4r"], "higher")
    return out


def extract_fig11(d: dict) -> Metrics:
    out: Metrics = {}
    for r in d.get("allreduce", []) + d.get("lossy", []):
        k = (f"allreduce/{r['world']}n/{r['message_bytes']}B/{r['mode']}/"
             f"{r['cc']}{'/lossy' if r.get('lossy') else ''}")
        out[f"{k}/busbw_B_per_tick"] = (r["busbw_B_per_tick"], "higher")
        out[f"{k}/ticks"] = (r["ticks"], "lower")
        out[f"{k}/retransmissions"] = (r["retransmissions"], "lower")
        out[f"{k}/tail_dropped"] = (r["tail_dropped"], "lower")
    return out


def extract_census(d: dict) -> Metrics:
    """Host-sync census (``BENCH_sync_census.json``, written by
    ``python -m repro.analysis --census``): device<->host transfers per
    simulated tick, per fig workload.  Strictly lower-is-better — the
    fused simulator core (ROADMAP item 2) drives these toward ~0, and
    nothing may quietly add a new per-tick sync."""
    out: Metrics = {}
    for fig, c in sorted(d.get("census", {}).items()):
        out[f"{fig}/d2h_per_tick"] = (c["d2h_per_tick"], "lower")
        out[f"{fig}/h2d_per_tick"] = (c["h2d_per_tick"], "lower")
    return out


EXTRACTORS = {"fig6": extract_fig6, "fig10": extract_fig10,
              "fig11": extract_fig11, "census": extract_census}


def compare(fig: str, base: Metrics, fresh: Metrics, *,
            tolerance: float, abs_slack: float) -> Tuple[list, list]:
    """Returns ``(failures, lines)`` — human-readable report lines for
    every shared metric, failure strings for the regressed ones."""
    failures, lines = [], []
    for key in sorted(base):
        if key not in fresh:
            failures.append(f"{fig}:{key}: metric missing from fresh run")
            continue
        b, direction = base[key]
        f, _ = fresh[key]
        if direction == "higher":
            bad = f < b * (1 - tolerance) - abs_slack
        else:
            bad = f > b * (1 + tolerance) + abs_slack
        mark = "REGRESSED" if bad else "ok"
        lines.append(f"  [{mark:>9}] {key}: base={b} fresh={f} "
                     f"({direction} is better)")
        if bad:
            failures.append(
                f"{fig}:{key}: {f} vs baseline {b} "
                f"({direction} is better, tolerance={tolerance:.0%} "
                f"+{abs_slack} abs)")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pair", nargs=3, action="append", required=True,
                    metavar=("FIG", "BASELINE", "FRESH"),
                    help="figure key (fig6|fig10|fig11), committed "
                         "baseline JSON, fresh run JSON")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="relative tolerance (default 5%%)")
    ap.add_argument("--abs-slack", type=float, default=2.0,
                    help="absolute slack for small counters (default 2)")
    args = ap.parse_args(argv)

    all_failures = []
    for fig, base_path, fresh_path in args.pair:
        if fig not in EXTRACTORS:
            print(f"error: unknown figure {fig!r} "
                  f"(choose from {sorted(EXTRACTORS)})")
            return 1
        try:
            with open(base_path) as f:
                base_doc = json.load(f)
            with open(fresh_path) as f:
                fresh_doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            all_failures.append(f"{fig}: cannot load inputs: {e}")
            continue
        if base_doc.get("mode") != fresh_doc.get("mode"):
            all_failures.append(
                f"{fig}: mode mismatch (baseline "
                f"{base_doc.get('mode')!r} vs fresh "
                f"{fresh_doc.get('mode')!r}) — rerun with matching flags")
            continue
        base = EXTRACTORS[fig](base_doc)
        fresh = EXTRACTORS[fig](fresh_doc)
        if not base:
            all_failures.append(f"{fig}: baseline has no metrics")
            continue
        failures, lines = compare(fig, base, fresh,
                                  tolerance=args.tolerance,
                                  abs_slack=args.abs_slack)
        print(f"{fig}: {len(base)} baseline metrics, "
              f"{len(failures)} regressed ({base_path} vs {fresh_path})")
        print("\n".join(lines))
        all_failures.extend(failures)

    if all_failures:
        print("\nPERF REGRESSION:")
        for f in all_failures:
            print(f"  {f}")
        return 1
    print("\nno perf regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
