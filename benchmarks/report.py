"""Generate EXPERIMENTS.md §Dry-run and §Roofline from a sweep JSONL.

  PYTHONPATH=src python -m benchmarks.report \
      --jsonl results/roofline_baseline2.jsonl --out results/roofline.md
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

from repro.common.config import SHAPES_BY_NAME
from repro.configs import get_config
from repro.models import params as P
from repro.models.model import Model

HW = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}


def load_cells(path: str) -> Dict:
    cells = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def param_counts(arch: str):
    cfg = get_config(arch)
    spec = Model(cfg).param_spec()
    total = P.count_params(spec)
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    active = total
    if cfg.n_experts:
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        expert_p = 3 * cfg.d_model * cfg.moe_d_ff
        active = total - n_moe_layers * expert_p * (cfg.n_experts - cfg.top_k)
    return total, active, embed


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPES_BY_NAME[shape_name]
    total, active, embed = param_counts(arch)
    n = active - embed // 2          # non-embedding active params
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch        # decode: 1 token/seq


def fraction(r) -> float:
    t = r["terms"]
    dom = max(t.values())
    return (t["compute_s"] / dom) if dom > 0 else 0.0


def bottleneck_advice(r) -> str:
    b = r["bottleneck"]
    if b == "memory_s":
        return ("fuse / avoid materializing the largest intermediates "
                "(attention scores, logits) and quantize the largest "
                "resident streams (KV cache)")
    if b == "collective_s":
        return ("re-shard to remove the dominant collective (expert "
                "layout, gradient compression on the pod axis)")
    return "increase arithmetic intensity per byte (compute-bound: good)"


def render(cells: Dict, title: str) -> str:
    lines = []
    lines.append(f"### {title}\n")
    lines.append("| arch | shape | mesh | compute (s) | memory (s) | "
                 "collective (s) | bottleneck | roofline frac | "
                 "MODEL/HLO flops | per-dev temp (GiB) | compile (s) |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    archs = sorted({k[0] for k in cells})
    for arch in archs:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            for mesh in ("16x16", "2x16x16"):
                r = cells.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] == "skip":
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — "
                                 f"| skip (DESIGN.md) | — | — | — | — |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | FAIL "
                                 f"| | | | | | | |")
                    continue
                t = r["terms"]
                mf = model_flops(arch, shape)
                hlo_global = r["hlo_flops_per_device"] * r["chips"]
                ratio = mf / hlo_global if hlo_global else 0
                temp = r["memory"]["temp_bytes"] / 2**30
                lines.append(
                    f"| {arch} | {shape} | {mesh} "
                    f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
                    f"| {t['collective_s']:.3f} "
                    f"| {r['bottleneck'].replace('_s','')} "
                    f"| {fraction(r):.3f} | {ratio:.2f} "
                    f"| {temp:.1f} | {r.get('compile_s','')} |")
    return "\n".join(lines) + "\n"


def render_dryrun(cells: Dict) -> str:
    lines = ["### Per-cell dry-run detail (single-pod)\n"]
    lines.append("| arch | shape | per-dev args (GiB) | per-dev temp (GiB) "
                 "| top collectives (GiB/device) |")
    lines.append("|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "16x16" or r["status"] != "ok":
            continue
        m = r["memory"]
        coll = "; ".join(f"{k}:{v/2**30:.1f}" for k, v in
                         list(r["coll_breakdown"].items())[:3]) or "none"
        lines.append(f"| {arch} | {shape} "
                     f"| {m['argument_bytes']/2**30:.2f} "
                     f"| {m['temp_bytes']/2**30:.2f} | {coll} |")
    return "\n".join(lines) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/roofline_baseline2.jsonl")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--title", default="Baseline (paper-faithful)")
    args = ap.parse_args(argv)
    cells = load_cells(args.jsonl)
    md = render(cells, args.title) + "\n" + render_dryrun(cells)
    with open(args.out, "w") as f:
        f.write(md)
    n_ok = sum(1 for r in cells.values() if r["status"] == "ok")
    n_skip = sum(1 for r in cells.values() if r["status"] == "skip")
    print(f"[report] {len(cells)} cells ({n_ok} ok, {n_skip} skip) "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
