"""Paper Fig. 7 analogue: AES on the BALBOA datapath vs on the host CPU.

On-datapath: the Pallas AES kernel fused into the jitted service chain —
one pass over the packet batch, zero host involvement ("scheduling of
execution is a non-existing problem").
Host path: payloads staged to host memory, encrypted per-buffer with a
doorbell-poll-style dispatch (one call per buffer), staged back — the
paper's CPU+OpenSSL configuration, minus OpenSSL's AES-NI (we report the
architectural gap, which on real hardware is compounded by the FPGA's
line rate; see EXPERIMENTS.md)."""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import telemetry as tm
from repro.core.services import AesService, ServiceChain
from repro.kernels import ops
from repro.kernels.ref import expand_key

KEY = np.arange(16, dtype=np.uint8)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="64 KB transfer only (CI bench job)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    args = ap.parse_args(argv)

    reg = tm.MetricRegistry()
    results = {"mode": "smoke" if args.smoke else "full", "transfers": {}}
    rk = expand_key(KEY)
    for total_kb in ((64,) if args.smoke else (64, 512, 4096)):
        n_pkts = total_kb * 1024 // 4096
        pay = np.random.default_rng(0).integers(
            0, 256, (n_pkts, 4096), dtype=np.uint8)
        plen = np.full(n_pkts, 4096, np.int32)

        # --- on-datapath: fused chain, one jitted pass -------------------
        chain = ServiceChain(on_path=[AesService(key=KEY)])
        payj = jnp.asarray(pay)
        plenj = jnp.asarray(plen)
        us = time_fn(lambda: chain.process(payj, plenj), iters=5)
        mbs = total_kb / 1024 / (us / 1e6) * 1e3 / 1e3
        emit(f"fig7_aes_onpath_{total_kb}KB", us,
             f"MBps={total_kb/1024/(us/1e6):.1f}")
        on_us = us

        # --- host path: per-buffer dispatch + staging copies --------------
        t0 = time.perf_counter()
        iters = 1 if args.smoke else 3
        for _ in range(iters):
            out = np.empty_like(pay)
            for i in range(n_pkts):             # doorbell-per-buffer
                blocks = jnp.asarray(pay[i].reshape(256, 16))
                ct = ops.aes_ecb(blocks, rk, impl="ref")
                out[i] = np.asarray(ct).reshape(4096)   # stage back
        host_us = (time.perf_counter() - t0) / iters * 1e6
        emit(f"fig7_aes_host_{total_kb}KB", host_us,
             f"MBps={total_kb/1024/(host_us/1e6):.1f};"
             f"speedup={host_us/on_us:.1f}x")
        results["transfers"][str(total_kb)] = {
            "onpath_us": round(on_us, 1), "host_us": round(host_us, 1),
            "speedup": round(host_us / on_us, 2)}
        reg.gauge(f"fig7/{total_kb}KB/speedup", host_us / on_us)

    results["telemetry"] = reg.flat()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
