"""Benchmark aggregator — one harness per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus section markers).

The roofline sweep (40 cells x 2 meshes) is NOT run from here (it takes
~40 min of fresh-process compiles); run it via
``python -m benchmarks.roofline`` — results land in results/*.jsonl and
EXPERIMENTS.md.  A summary of the latest sweep is echoed below if
present."""
from __future__ import annotations

import json
import os
import traceback


def _section(name):
    print(f"# --- {name} ---")


def main() -> None:
    from benchmarks import (fig4_transport, fig5_breakdown, fig6_multiqp,
                            fig7_aes, fig8_dpi, fig10_dlrm, fig11_allreduce,
                            table2_resources)
    print("name,us_per_call,derived")
    for mod in (fig4_transport, fig5_breakdown, fig6_multiqp, fig7_aes,
                fig8_dpi, table2_resources, fig10_dlrm, fig11_allreduce):
        _section(mod.__name__)
        try:
            mod.main()
        except Exception as e:           # keep the suite running
            print(f"{mod.__name__},nan,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()

    # echo the roofline sweep summary if a baseline file exists
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "roofline_baseline2.jsonl")
    if os.path.exists(path):
        _section("roofline (latest sweep summary)")
        n_ok = n_skip = n_fail = 0
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                s = r.get("status")
                n_ok += s == "ok"
                n_skip += s == "skip"
                n_fail += s == "FAIL"
        print(f"roofline_cells,0.0,ok={n_ok};skip={n_skip};fail={n_fail}")


if __name__ == "__main__":
    main()
