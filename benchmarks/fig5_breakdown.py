"""Paper Fig. 5 analogue: end-to-end latency breakdown per datapath
element, for 64 B and 4 KB (MTU) packets.

Each stage is timed as its jitted kernel: RX header pipeline (the packet
processing pipeline), ICRC, retransmission mux (buffer hold+ack), AES,
DPI, DLRM preprocessing.  The paper's finding to reproduce: the packet
processing pipeline — not the checksum — dominates the stack latency.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core import telemetry as tm
from repro.core.retransmit import RetransmissionBuffer
from repro.core.services import AesService, DpiService, PreprocService
from repro.data.dpi_dataset import make_dataset
from repro.kernels.dpi_mlp import train_dpi_params
from repro.kernels import ops

BATCH = 16


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="64 B stage only, short DPI training (CI)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    args = ap.parse_args(argv)

    reg = tm.MetricRegistry()
    results = {"mode": "smoke" if args.smoke else "full", "stages": {}}
    rng = np.random.default_rng(0)
    x, y = make_dataset(256 if args.smoke else 1024, seed=0)
    dpi_params = train_dpi_params(x, y, steps=30 if args.smoke else 150)

    def stage(name: str, size: int, us: float):
        emit(f"fig5_{name}_{size}B", us / BATCH, "per-packet")
        results["stages"].setdefault(str(size), {})[name] = \
            round(us / BATCH, 3)
        reg.gauge(f"fig5/{name}/{size}B_us_per_pkt", us / BATCH)

    for size in ((64,) if args.smoke else (64, 4096)):
        pay = rng.integers(0, 256, (BATCH, 4096), dtype=np.uint8)
        plen = np.full(BATCH, size, np.int32)
        payj, plenj = jnp.asarray(pay), jnp.asarray(plen)

        # 1) packet-processing pipeline (header FSMs)
        pkts = [pk.Packet(opcode=pk.WRITE_ONLY, qpn=1, psn=i,
                          payload=pay[i, :size], vaddr=0, dma_len=size)
                for i in range(BATCH)]
        batch = {k: jnp.asarray(v)
                 for k, v in pk.batch_from_packets(pkts).items()}
        tables = pipe.make_rx_tables(8)
        # the engine donates its tables arg: clone per timed call
        us = time_fn(
            lambda: pipe.rx_pipeline(pipe.clone_tables(tables), batch))
        stage("rx_pipeline", size, us)

        # 2) ICRC
        us = time_fn(lambda: ops.crc32(payj, plenj))
        stage("icrc", size, us)

        # 3) retransmission buffering (host mux)
        def retx_cycle():
            rb = RetransmissionBuffer()
            for p in pkts:
                rb.hold(1, p, 0)
            rb.ack(1, pkts[-1].psn)
            return 0
        import time as _t
        t0 = _t.perf_counter()
        for _ in range(20):
            retx_cycle()
        us = (_t.perf_counter() - t0) / 20 * 1e6
        stage("retx_mux", size, us)

        # 4) AES on-path
        aes = AesService(key=np.arange(16, dtype=np.uint8))
        us = time_fn(lambda: aes(payj, plenj))
        stage("aes", size, us)

        # 5) DPI parallel-path
        dpi = DpiService(params=dpi_params)
        us = time_fn(lambda: dpi(payj, plenj))
        stage("dpi", size, us)

        # 6) DLRM preprocessing
        pre = PreprocService()
        us = time_fn(lambda: pre(payj, plenj))
        stage("preproc", size, us)

    results["telemetry"] = reg.flat()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
