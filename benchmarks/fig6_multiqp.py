"""Paper Fig. 6 analogue: bandwidth distribution across multiple QPs.

Batched transmissions of competing QPs are interleaved; the arbiter
(flow control + per-QP windows) must share the link fairly.  Metric:
coefficient of variation of per-QP delivered bytes (paper: visually even
bars across QPs)."""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network


def run(n_qps: int, size: int = 32768, rounds: int = 8):
    net = Network(2, LinkConfig(latency_ticks=2,
                                bandwidth_pkts_per_tick=4, seed=4))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qps = [a.init_rdma(size * 2, b)[0] for _ in range(n_qps)]
    rng = np.random.default_rng(0)
    datas = [rng.integers(0, 256, size, dtype=np.uint8) for _ in qps]
    for _ in range(rounds):
        for q, d in zip(qps, datas):     # interleaved batched writes
            a.rdma_write(q, d)
        run_network([a, b], max_ticks=100_000)
    per_qp = np.array([b.check_completed(i + 1) for i in range(n_qps)],
                      float) * size
    cv = per_qp.std() / per_qp.mean()
    return per_qp, cv


def main():
    for n in (2, 4, 8, 16):
        per_qp, cv = run(n)
        emit(f"fig6_multiqp_{n}qps", 0.0,
             f"cv={cv:.4f};bytes_per_qp={int(per_qp.mean())}")
        assert cv < 0.05, f"unfair arbitration across {n} QPs: cv={cv}"


if __name__ == "__main__":
    main()
