"""Paper Fig. 6 analogue: multi-QP scaling, fairness, and incast.

Three experiments:

1. **Scaling sweep** (the PR's acceptance metric): aggregate RX-pipeline
   throughput (packets/sec) vs. QP count, 1 -> 512, for the per-packet
   scan oracle and the batched multi-QP engine on identical traces.
   The oracle's sequential depth is the batch size; the batched engine's
   is the longest per-QP segment, so its advantage grows with QP count
   (the paper's axis: "hundreds of QPs at line rate").  Asserts >= 5x
   at 256 QPs.

2. **Fairness** (the original Fig. 6 reading): competing QPs through the
   ACK-clocked arbiter must share a shaped link evenly — coefficient of
   variation of per-QP delivered bytes stays < 5%.

3. **Incast**: N senders converge on one switch port (shared egress
   queue, drop-tail).  Reports goodput, tail drops and retransmissions
   — the congestion scenario the point-to-point model could not express.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.netsim import (FabricConfig, LinkConfig, Network,
                               incast_scenario)
from repro.core.rdma import RdmaNode, run_network

SWEEP_QPS = (1, 4, 16, 64, 256, 512)
SWEEP_BATCH = 4096


def _trace_batch(n_qps: int, n_pkts: int, seed: int = 0):
    """An in-sequence multi-QP header trace (the steady-state hot path)."""
    rng = np.random.default_rng(seed)
    qpn = np.sort(rng.integers(0, n_qps, n_pkts)).astype(np.int32)
    psn = np.zeros(n_pkts, np.int32)
    nxt = {}
    for i, q in enumerate(qpn):
        psn[i] = nxt.get(q, 0)
        nxt[q] = psn[i] + 1
    return {
        "qpn": jnp.asarray(qpn),
        "opcode": jnp.full(n_pkts, pk.WRITE_ONLY, jnp.int32),
        "psn": jnp.asarray(psn),
        "plen": jnp.full(n_pkts, 64, jnp.int32),
        "vaddr": jnp.zeros(n_pkts, jnp.int32),
        "dma_len": jnp.full(n_pkts, 64, jnp.int32),
        "ack_req": jnp.zeros(n_pkts, jnp.int32),
        "valid": jnp.ones(n_pkts, jnp.int32),
    }


def _pps(fn, n_qps: int, n_pkts: int, iters: int = 11) -> float:
    """Median aggregate packets/sec of one jitted RX step."""
    batch = _trace_batch(n_qps, n_pkts)
    tables = pipe.make_rx_tables(n_qps, initial_credits=1 << 30)
    us = time_fn(lambda: fn(tables, batch)[1].accept, iters=iters)
    return n_pkts * 1e6 / us


def sweep():
    """Aggregate throughput vs. QP count, oracle vs. batched engine."""
    speedup_at = {}
    for n_qps in SWEEP_QPS:
        pps_scan = _pps(pipe.rx_pipeline, n_qps, SWEEP_BATCH)
        pps_batched = _pps(pipe.rx_pipeline_batched, n_qps, SWEEP_BATCH)
        ratio = pps_batched / pps_scan
        speedup_at[n_qps] = ratio
        emit(f"fig6_sweep_{n_qps}qps", 1e6 * SWEEP_BATCH / pps_batched,
             f"scan_pps={pps_scan:.0f};batched_pps={pps_batched:.0f};"
             f"speedup={ratio:.1f}x")
    assert speedup_at[256] >= 5.0, (
        f"batched engine only {speedup_at[256]:.1f}x over the scan oracle "
        f"at 256 QPs (acceptance floor: 5x)")
    return speedup_at


def fairness(n_qps: int, size: int = 32768, rounds: int = 8):
    """Competing QPs share a shaped link evenly (original Fig. 6)."""
    net = Network(2, LinkConfig(latency_ticks=2,
                                bandwidth_pkts_per_tick=4, seed=4))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qps = [a.init_rdma(size * 2, b)[0] for _ in range(n_qps)]
    rng = np.random.default_rng(0)
    datas = [rng.integers(0, 256, size, dtype=np.uint8) for _ in qps]
    for _ in range(rounds):
        for q, d in zip(qps, datas):     # interleaved batched writes
            a.rdma_write(q, d)
        run_network([a, b], max_ticks=100_000)
    per_qp = np.array([b.check_completed(i + 1) for i in range(n_qps)],
                      float) * size
    cv = per_qp.std() / per_qp.mean()
    return per_qp, cv


def incast(n_senders: int = 8, message_bytes: int = 32768):
    """N-to-1 congestion through the switched fabric."""
    res = incast_scenario(
        n_senders, message_bytes=message_bytes,
        fabric_cfg=FabricConfig(port_bandwidth=4, port_delay=2,
                                queue_capacity=24, seed=7))
    hot = res.fabric.port_stats[0]
    goodput = n_senders * message_bytes / max(res.ticks, 1)
    retx = sum(s.stats.retransmissions for s in res.senders)
    emit(f"fig6_incast_{n_senders}to1", 0.0,
         f"ticks={res.ticks};goodput_Bptick={goodput:.1f};"
         f"tail_dropped={hot.tail_dropped};retx={retx};"
         f"max_queue={hot.max_depth}")
    assert hot.tail_dropped > 0, "incast produced no congestion drops"
    assert res.receiver.stats.accepted == n_senders * pk.read_resp_npkts(
        message_bytes), "incast lost data"


def main():
    sweep()
    for n in (2, 4, 8, 16):
        per_qp, cv = fairness(n)
        emit(f"fig6_multiqp_{n}qps", 0.0,
             f"cv={cv:.4f};bytes_per_qp={int(per_qp.mean())}")
        assert cv < 0.05, f"unfair arbitration across {n} QPs: cv={cv}"
    incast()


if __name__ == "__main__":
    main()
