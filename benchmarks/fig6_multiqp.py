"""Paper Fig. 6 analogue: multi-QP scaling, fairness, incast, and the
ECN/DCQCN congestion-control comparison.

Five experiments:

1. **Scaling sweep** (PR 1's acceptance metric): aggregate RX-pipeline
   throughput (packets/sec) vs. QP count, 1 -> 512, for the per-packet
   scan oracle and the batched multi-QP engine on identical traces.
   The oracle's sequential depth is the batch size; the batched engine's
   is the longest per-QP segment, so its advantage grows with QP count
   (the paper's axis: "hundreds of QPs at line rate").  Asserts >= 5x
   at 256 QPs.

2. **Fairness** (the original Fig. 6 reading): competing QPs through the
   ACK-clocked arbiter must share a shaped link evenly — coefficient of
   variation of per-QP delivered bytes stays < 5%.

3. **Incast**: N senders converge on one switch port (shared egress
   queue, drop-tail).  Reports goodput, tail drops and retransmissions
   — the congestion scenario the point-to-point model could not express.

4. **Incast CC sweep** (PR 2's acceptance metric): the same incast, CC
   off (``ack_clocked``) vs on (``dcqcn``), over growing fan-in, on an
   *identical* ECN-marking fabric (the off arm simply ignores CNPs).
   Asserts that at 8:1 DCQCN gives strictly fewer drop-tail drops and
   >= 1.3x goodput.

5. **Multipath sweep** (PR 6's acceptance metric): the same incast
   over a 2-spine leaf-spine ``ClosFabric`` with per-packet spray and
   asymmetric spine delays, go-back-N vs selective-repeat RX, plus a
   single-path (ECMP) arm and a mid-transfer spine-failure arm.
   Asserts SR >= 1.3x GBN goodput with strictly fewer retransmitted
   packets — reorder alone must not trigger the loss path.

``--smoke`` runs tiny CC + multipath sweeps only (the CI bench job);
``--json P`` writes all results to ``P`` for the bench trajectory.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core import telemetry as tm
from repro.core.netsim import (FabricConfig, LinkConfig, Network,
                               clos_incast_scenario, dcqcn_fabric_profile,
                               incast_scenario)
from repro.core.rdma import RdmaNode, run_network

SWEEP_QPS = (1, 4, 16, 64, 256, 512)
SWEEP_BATCH = 4096

# one fabric for both CC arms: shallow enough that an 8x window
# oversubscription genuinely congests, ECN thresholds the ack_clocked
# arm simply never reacts to
CC_FABRIC = dcqcn_fabric_profile()


def _trace_batch(n_qps: int, n_pkts: int, seed: int = 0):
    """An in-sequence multi-QP header trace (the steady-state hot path)."""
    rng = np.random.default_rng(seed)
    qpn = np.sort(rng.integers(0, n_qps, n_pkts)).astype(np.int32)
    psn = np.zeros(n_pkts, np.int32)
    nxt = {}
    for i, q in enumerate(qpn):
        psn[i] = nxt.get(q, 0)
        nxt[q] = psn[i] + 1
    return {
        "qpn": jnp.asarray(qpn),
        "opcode": jnp.full(n_pkts, pk.WRITE_ONLY, jnp.int32),
        "psn": jnp.asarray(psn),
        "plen": jnp.full(n_pkts, 64, jnp.int32),
        "vaddr": jnp.zeros(n_pkts, jnp.int32),
        "dma_len": jnp.full(n_pkts, 64, jnp.int32),
        "ack_req": jnp.zeros(n_pkts, jnp.int32),
        "valid": jnp.ones(n_pkts, jnp.int32),
    }


def _pps(fn, n_qps: int, n_pkts: int, iters: int = 11) -> float:
    """Median aggregate packets/sec of one jitted RX step."""
    batch = _trace_batch(n_qps, n_pkts)
    tables = pipe.make_rx_tables(n_qps, initial_credits=1 << 30)
    # the engine donates its tables arg: clone per timed call
    us = time_fn(lambda: fn(pipe.clone_tables(tables), batch)[1].accept,
                 iters=iters)
    return n_pkts * 1e6 / us


def sweep():
    """Aggregate throughput vs. QP count, oracle vs. batched engine."""
    speedup_at = {}
    for n_qps in SWEEP_QPS:
        pps_scan = _pps(pipe.rx_pipeline, n_qps, SWEEP_BATCH)
        pps_batched = _pps(pipe.rx_pipeline_batched, n_qps, SWEEP_BATCH)
        ratio = pps_batched / pps_scan
        speedup_at[n_qps] = ratio
        emit(f"fig6_sweep_{n_qps}qps", 1e6 * SWEEP_BATCH / pps_batched,
             f"scan_pps={pps_scan:.0f};batched_pps={pps_batched:.0f};"
             f"speedup={ratio:.1f}x")
    assert speedup_at[256] >= 5.0, (
        f"batched engine only {speedup_at[256]:.1f}x over the scan oracle "
        f"at 256 QPs (acceptance floor: 5x)")
    return speedup_at


def fairness(n_qps: int, size: int = 32768, rounds: int = 8):
    """Competing QPs share a shaped link evenly (original Fig. 6)."""
    net = Network(2, LinkConfig(latency_ticks=2,
                                bandwidth_pkts_per_tick=4, seed=4))
    a, b = RdmaNode(0, net), RdmaNode(1, net)
    qps = [a.init_rdma(size * 2, b)[0] for _ in range(n_qps)]
    rng = np.random.default_rng(0)
    datas = [rng.integers(0, 256, size, dtype=np.uint8) for _ in qps]
    for _ in range(rounds):
        for q, d in zip(qps, datas):     # interleaved batched writes
            a.rdma_write(q, d)
        run_network([a, b], max_ticks=100_000)
    per_qp = np.array([b.check_completed(i + 1) for i in range(n_qps)],
                      float) * size
    cv = per_qp.std() / per_qp.mean()
    return per_qp, cv


def incast(n_senders: int = 8, message_bytes: int = 32768):
    """N-to-1 congestion through the switched fabric."""
    res = incast_scenario(
        n_senders, message_bytes=message_bytes,
        fabric_cfg=FabricConfig(port_bandwidth=4, port_delay=2,
                                queue_capacity=24, seed=7))
    hot = res.fabric.port_stats[0]
    goodput = n_senders * message_bytes / max(res.ticks, 1)
    retx = sum(s.stats.retransmissions for s in res.senders)
    emit(f"fig6_incast_{n_senders}to1", 0.0,
         f"ticks={res.ticks};goodput_Bptick={goodput:.1f};"
         f"tail_dropped={hot.tail_dropped};retx={retx};"
         f"max_queue={hot.max_depth}")
    assert hot.tail_dropped > 0, "incast produced no congestion drops"
    assert res.receiver.stats.accepted == n_senders * pk.read_resp_npkts(
        message_bytes), "incast lost data"


def fused_epoch_equivalence(n_senders: int = 4,
                            message_bytes: int = 32768) -> dict:
    """The canonical drop-tail incast driven two ways: per-tick
    stepping vs the fused epoch core (``run_network(epoch_mode=
    'fused')``).  Every transport-visible counter must be bit-identical
    — tests/test_fused_core.py pins the full world state at unit scale,
    this pins the contract at bench scale and records what the fused
    driver costs/saves in wall clock (the tick metrics are what the
    regression gate sees; wall time is informational)."""
    import time
    arms = {}
    for mode in ("tick", "fused"):
        t0 = time.perf_counter()
        res = incast_scenario(
            n_senders, message_bytes=message_bytes,
            fabric_cfg=FabricConfig(port_bandwidth=4, port_delay=2,
                                    queue_capacity=24, seed=7),
            epoch_mode=mode)
        wall = time.perf_counter() - t0
        hot = res.fabric.port_stats[0]
        arms[mode] = {
            "ticks": int(res.ticks),
            "wall_s": round(wall, 4),
            "accepted": int(res.receiver.stats.accepted),
            "tail_dropped": int(hot.tail_dropped),
            "max_queue": int(hot.max_depth),
            "retransmissions": int(sum(s.stats.retransmissions
                                       for s in res.senders)),
        }
    keys = ("ticks", "accepted", "tail_dropped", "max_queue",
            "retransmissions")
    tick = {k: arms["tick"][k] for k in keys}
    fused = {k: arms["fused"][k] for k in keys}
    assert tick == fused, \
        f"fused epoch diverged from per-tick: {fused} vs {tick}"
    emit(f"fig6_fused_epoch_{n_senders}to1",
         arms["fused"]["wall_s"] * 1e6,
         f"ticks={tick['ticks']};tick_wall_s={arms['tick']['wall_s']};"
         f"fused_wall_s={arms['fused']['wall_s']}")
    return arms


def _incast_cc_arm(n_senders: int, message_bytes: int, cc: str) -> dict:
    res = incast_scenario(n_senders, message_bytes=message_bytes,
                          fabric_cfg=CC_FABRIC, congestion_control=cc)
    hot = res.fabric.port_stats[0]
    line = CC_FABRIC.port_bandwidth * pk.MTU        # payload B/tick
    goodput = n_senders * message_bytes / max(res.ticks, 1)
    assert res.receiver.stats.accepted == sum(
        pk.read_resp_npkts(len(d)) for d in res.payloads), \
        f"incast ({cc}) lost data"
    return {
        "cc": cc, "fan_in": n_senders, "message_bytes": message_bytes,
        "ticks": res.ticks, "goodput_B_per_tick": round(goodput, 2),
        "utilization": round(goodput / line, 4),
        "tail_dropped": hot.tail_dropped,
        "ecn_marked": hot.ecn_marked,
        "max_queue": hot.max_depth,
        "retransmissions": sum(s.stats.retransmissions
                               for s in res.senders),
        "cnp_tx": res.receiver.stats.cnp_tx,
        "cnp_rx": sum(s.stats.cnp_rx for s in res.senders),
        "qp_deaths": sum(len(s.retx.exhausted) for s in res.senders),
    }


def incast_cc_sweep(fan_ins=(2, 4, 8, 16), message_bytes: int = 1 << 20,
                    check: bool = True) -> list:
    """CC off vs on over growing fan-in (the PR's acceptance sweep)."""
    results = []
    for n in fan_ins:
        off = _incast_cc_arm(n, message_bytes, "ack_clocked")
        on = _incast_cc_arm(n, message_bytes, "dcqcn")
        results += [off, on]
        gain = on["goodput_B_per_tick"] / max(off["goodput_B_per_tick"], 1e-9)
        emit(f"fig6_incast_cc_{n}to1", 0.0,
             f"off_drops={off['tail_dropped']};on_drops={on['tail_dropped']};"
             f"off_util={off['utilization']:.3f};"
             f"on_util={on['utilization']:.3f};goodput_gain={gain:.2f}x;"
             f"on_cnps={on['cnp_rx']}")
        if check and n >= 8:
            assert on["tail_dropped"] < off["tail_dropped"], (
                f"{n}:1 incast: DCQCN should drop strictly less "
                f"({on['tail_dropped']} vs {off['tail_dropped']})")
            assert gain >= 1.3, (
                f"{n}:1 incast: DCQCN goodput gain {gain:.2f}x < 1.3x")
    return results


def _multipath_arm(n_senders: int, message_bytes: int, rx_mode: str,
                   path_select: str, fail_spine_at=None) -> dict:
    res = clos_incast_scenario(n_senders, message_bytes=message_bytes,
                               rx_mode=rx_mode, path_select=path_select,
                               fail_spine_at=fail_spine_at)
    fab = res.fabric
    for i, data in enumerate(res.payloads):
        want = res.senders[i].expected_completions(len(data))
        got = res.receiver.check_completed(i + 1)
        assert got == want, (
            f"clos incast ({rx_mode}/{path_select}) lost data: sender "
            f"{i} completed {got}/{want} messages")
    goodput = n_senders * message_bytes / max(res.ticks, 1)
    return {
        "rx_mode": rx_mode, "path_select": path_select,
        "fan_in": n_senders, "message_bytes": message_bytes,
        "fail_spine_at": fail_spine_at, "ticks": res.ticks,
        "goodput_B_per_tick": round(goodput, 2),
        "spine_pkts": list(fab.spine_pkts),
        "tail_dropped": fab.total_tail_dropped,
        "retransmissions": sum(s.stats.retransmissions
                               for s in res.senders),
        "ooo_naks": sum(s.stats.ooo_nak for s in res.senders)
                    + res.receiver.stats.ooo_nak,
        "sacked": sum(s.stats.sacked for s in res.senders),
        "alive_spines": len(fab.alive_paths),
        "failure_dropped": fab.failure_dropped,
    }


def multipath_sweep(fan_ins=(2, 4), message_bytes: int = 65536,
                    check: bool = True) -> list:
    """Spray vs single-path over the Clos fabric, GBN vs SR (PR 6).

    The asymmetric spine delays make per-packet spray genuinely
    reorder every flow; go-back-N misreads the reorder as loss and
    re-sends whole windows while selective repeat absorbs it, so SR
    must win on both goodput and retransmission count.
    """
    results = []
    for n in fan_ins:
        gbn = _multipath_arm(n, message_bytes, "go_back_n", "spray")
        sr = _multipath_arm(n, message_bytes, "selective_repeat", "spray")
        one = _multipath_arm(n, message_bytes, "selective_repeat", "ecmp")
        results += [gbn, sr, one]
        gain = sr["goodput_B_per_tick"] / max(gbn["goodput_B_per_tick"],
                                              1e-9)
        emit(f"fig6_multipath_{n}to1", 0.0,
             f"gbn_retx={gbn['retransmissions']};"
             f"sr_retx={sr['retransmissions']};"
             f"sr_goodput_gain={gain:.2f}x;"
             f"spray_spines={sr['spine_pkts']};"
             f"ecmp_spines={one['spine_pkts']}")
        if check:
            assert all(p > 0 for p in sr["spine_pkts"]), \
                f"{n}:1 spray left a spine idle: {sr['spine_pkts']}"
            assert gain >= 1.3, (
                f"{n}:1 spray incast: SR goodput only {gain:.2f}x of "
                f"go-back-N (acceptance floor: 1.3x)")
            assert sr["retransmissions"] < gbn["retransmissions"], (
                f"{n}:1 spray incast: SR retransmitted "
                f"{sr['retransmissions']} >= GBN "
                f"{gbn['retransmissions']}")
    fail = _multipath_arm(max(fan_ins), message_bytes,
                          "selective_repeat", "spray", fail_spine_at=10)
    results.append(fail)
    emit("fig6_multipath_spine_failure", 0.0,
         f"ticks={fail['ticks']};retx={fail['retransmissions']};"
         f"dropped_in_flight={fail['failure_dropped']};"
         f"spine_pkts={fail['spine_pkts']}")
    if check:
        assert fail["alive_spines"] < len(fail["spine_pkts"]), \
            "spine failure arm never actually killed a spine"
    return results


def traced_incast(n_senders: int = 8, message_bytes: int = 32768,
                  trace_path=None) -> dict:
    """The acceptance scenario: an 8:1 Clos incast with a mid-run spine
    failure, flight-recorded end to end.  Exports a Perfetto JSON trace
    (tracks = ports / uplinks / spines / QPs) and asserts the trace's
    event counts reconcile exactly with the ``MetricRegistry``
    snapshot."""
    rec = tm.FlightRecorder(capacity=1 << 20)
    res = clos_incast_scenario(n_senders, message_bytes=message_bytes,
                               rx_mode="selective_repeat",
                               path_select="spray", fail_spine_at=10,
                               recorder=rec)
    reg, _ = tm.instrument(fabric=res.fabric,
                           nodes=[res.receiver] + res.senders,
                           recorder=rec)
    snap = reg.snapshot()
    assert rec.dropped_events == 0, "ring wrapped: raise capacity"
    by = snap["flight"]["by_kind"]
    # exact reconciliation: every counted occurrence has its event
    assert by.get("inject", 0) + by.get("wire_drop", 0) == \
        snap["fabric"]["injected"], \
        f"inject+wire_drop events != injected counter"
    retx = sum(s.stats.retransmissions for s in res.senders) \
        + res.receiver.stats.retransmissions
    assert by.get("retransmit", 0) == retx, \
        f"retransmit events {by.get('retransmit')} != stats {retx}"
    cnps = sum(s.stats.cnp_rx for s in res.senders) \
        + res.receiver.stats.cnp_rx
    assert by.get("cnp_rx", 0) == cnps
    # the fabric is quiescent: every admitted packet either drained or
    # was flushed by the spine failure
    assert by.get("enqueue", 0) == \
        by.get("dequeue", 0) + by.get("flush", 0), \
        "enqueue/dequeue/flush events do not balance"
    n_trace = len(rec.events())
    if trace_path:
        rec.export_chrome_trace(trace_path)
        emit("fig6_trace", 0.0,
             f"path={trace_path};events={n_trace};"
             f"kinds={len(by)}")
    return {"fan_in": n_senders, "message_bytes": message_bytes,
            "ticks": res.ticks, "trace_events": n_trace,
            "telemetry": reg.flat(snap)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CC sweep only (CI bench job)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    ap.add_argument("--trace", metavar="PATH",
                    help="export a Perfetto/Chrome-trace JSON of the "
                         "8:1 spine-failure incast to PATH")
    args = ap.parse_args(argv)

    results = {"mode": "smoke" if args.smoke else "full"}
    if args.smoke:
        results["incast_cc"] = incast_cc_sweep(
            fan_ins=(2, 8), message_bytes=65536, check=False)
        # the headline property must hold even at smoke scale: CC on
        # never drops more than CC off at 8:1
        by = {(r["fan_in"], r["cc"]): r for r in results["incast_cc"]}
        assert by[(8, "dcqcn")]["tail_dropped"] <= \
            by[(8, "ack_clocked")]["tail_dropped"], "smoke: DCQCN regressed"
        # PR 6's headline must hold even at smoke scale: SR >= 1.3x GBN
        # goodput under spray with fewer retransmissions (checked inside)
        results["multipath"] = multipath_sweep(
            fan_ins=(3,), message_bytes=32768)
        results["fused_epoch"] = fused_epoch_equivalence(
            n_senders=4, message_bytes=16384)
    else:
        results["sweep_speedup"] = {str(k): round(v, 2)
                                    for k, v in sweep().items()}
        fair = {}
        for n in (2, 4, 8, 16):
            per_qp, cv = fairness(n)
            emit(f"fig6_multiqp_{n}qps", 0.0,
                 f"cv={cv:.4f};bytes_per_qp={int(per_qp.mean())}")
            assert cv < 0.05, f"unfair arbitration across {n} QPs: cv={cv}"
            fair[str(n)] = round(float(cv), 5)
        results["fairness_cv"] = fair
        incast()
        results["incast_cc"] = incast_cc_sweep()
        results["multipath"] = multipath_sweep()
        results["fused_epoch"] = fused_epoch_equivalence()
    results["traced_incast"] = traced_incast(
        message_bytes=16384 if args.smoke else 32768,
        trace_path=args.trace)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
