"""Roofline sweep driver: runs every (arch x shape x mesh) dry-run cell
in a fresh subprocess (XLA compile caches would otherwise accumulate for
hours of compiles) and collects roofline terms into a JSONL file.

  PYTHONPATH=src python -m benchmarks.roofline --out results/roofline.jsonl
  PYTHONPATH=src python -m benchmarks.roofline --single gemma2-2b train_4k 16x16
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = (
    "gemma3-4b", "gemma2-27b", "gemma2-2b", "granite-3-2b", "xlstm-125m",
    "whisper-base", "deepseek-v3-671b", "deepseek-v2-236b", "qwen2-vl-72b",
    "recurrentgemma-9b",
)
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
MESHES = ("16x16", "2x16x16")

_CELL_SNIPPET = r"""
import json, sys
from repro.launch.dryrun import run_cell
arch, shape, mesh = sys.argv[1], sys.argv[2], sys.argv[3]
override = json.loads(sys.argv[4]) if len(sys.argv) > 4 else None
r = run_cell(arch, shape, mesh == "2x16x16", opt_override=override,
             verbose=False)
print("CELL_RESULT " + json.dumps(r))
"""


def run_one(arch: str, shape: str, mesh: str, override=None,
            timeout: int = 2400) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-c", _CELL_SNIPPET, arch, shape, mesh]
    if override:
        cmd.append(json.dumps(override))
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env, cwd=os.path.dirname(
                                  os.path.dirname(os.path.abspath(__file__))))
        for line in proc.stdout.splitlines():
            if line.startswith("CELL_RESULT "):
                return json.loads(line[len("CELL_RESULT "):])
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "FAIL",
                "error": (proc.stderr or proc.stdout)[-500:]}
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "FAIL",
                "error": f"timeout after {timeout}s"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/roofline.jsonl")
    ap.add_argument("--single", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args(argv)
    override = json.loads(args.override) if args.override else None

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skip"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    cells = ([tuple(args.single)] if args.single else
             [(a, s, m) for a in ARCHS for s in SHAPES for m in MESHES])
    with open(args.out, "a") as f:
        for arch, shape, mesh in cells:
            if (arch, shape, mesh) in done:
                continue
            t0 = time.time()
            r = run_one(arch, shape, mesh, override)
            r["wall_s"] = round(time.time() - t0, 1)
            f.write(json.dumps(r) + "\n")
            f.flush()
            stat = r.get("status")
            extra = ""
            if stat == "ok":
                t = r["terms"]
                extra = (f" compute={t['compute_s']*1e3:.1f}ms "
                         f"mem={t['memory_s']*1e3:.1f}ms "
                         f"coll={t['collective_s']*1e3:.1f}ms "
                         f"-> {r['bottleneck']}")
            elif stat == "FAIL":
                extra = " " + r.get("error", "")[:160].replace("\n", " ")
            print(f"[roofline] {arch} x {shape} ({mesh}): {stat}"
                  f" [{r['wall_s']}s]{extra}", flush=True)
    print("[roofline] sweep complete")


if __name__ == "__main__":
    main()
