"""Paper Figs. 10/11 analogue: DLRM preprocessing throughput + latency.

Three configurations, exactly Fig. 9's setups:
  ① vanilla: payload -> host buffer -> CPU preprocessing (per-record
     Python/numpy on a dedicated core) -> copy to device
  ② on-path preprocessing (fused Pallas kernel in the chain) but staged
     through a host buffer copy before device_put
  ③ full BALBOA: on-path preprocessing + direct-to-device placement
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from repro.core.services import PreprocService, ServiceChain
from repro.data import synthetic as syn

N_DENSE, N_SPARSE, MOD = 13, 26, 100_000
REC_W = N_DENSE + N_SPARSE


def _payloads(total_mb: float):
    recs_per_pkt = (4096 // 4) // REC_W
    n_pkts = int(total_mb * 1e6) // 4096
    n_rec = recs_per_pkt * n_pkts
    raw = syn.dlrm_shard(0, n_rec, N_DENSE, N_SPARSE)
    pay = np.zeros((n_pkts, 4096), np.uint8)
    rec_b = REC_W * 4
    flat = raw.view(np.uint8).reshape(n_rec, rec_b)
    for p in range(n_pkts):
        chunk = flat[p * recs_per_pkt:(p + 1) * recs_per_pkt]
        pay[p, :recs_per_pkt * rec_b] = chunk.reshape(-1)
    return raw, pay, n_rec


def cpu_preprocess(raw: np.ndarray) -> np.ndarray:
    dense = np.log1p(np.maximum(raw[:, :N_DENSE], 0).astype(np.float32))
    sparse = raw[:, N_DENSE:] % MOD
    return dense, sparse


def main():
    total_mb = 8.0
    raw, pay, n_rec = _payloads(total_mb)
    plen = jnp.asarray(np.full(len(pay), 4096, np.int32))
    payj = jnp.asarray(pay)

    # ① vanilla: host-buffer copy + CPU preprocessing + device copy
    t0 = time.perf_counter()
    host_buf = np.asarray(payj).copy()                # DMA to host buffer
    recs = host_buf.reshape(len(pay), -1)[:, :  (4096 // 4 // REC_W) * REC_W * 4]
    recs = recs.reshape(-1, REC_W * 4).view(np.int32)
    dense, sparse = cpu_preprocess(recs)
    d = jax.device_put((dense, sparse))
    jax.block_until_ready(d)
    t1 = time.perf_counter() - t0
    emit("fig10_vanilla_cpu", t1 * 1e6,
         f"MBps={total_mb/t1:.1f}")

    # ② on-path preproc + host bounce
    chain = ServiceChain(on_path=[PreprocService(
        n_dense=N_DENSE, n_sparse=N_SPARSE, modulus=MOD)])
    chain.process(payj, plen)                         # compile
    t0 = time.perf_counter()
    out, _ = chain.process(payj, plen)
    host = np.asarray(out)                            # bounce to host
    d = jax.device_put(host)
    jax.block_until_ready(d)
    t2 = time.perf_counter() - t0
    emit("fig10_onpath_hostcopy", t2 * 1e6, f"MBps={total_mb/t2:.1f}")

    # ③ full BALBOA: on-path preproc, result stays on device
    t0 = time.perf_counter()
    out, _ = chain.process(payj, plen)
    jax.block_until_ready(out)
    t3 = time.perf_counter() - t0
    emit("fig10_balboa_direct", t3 * 1e6,
         f"MBps={total_mb/t3:.1f};vs_vanilla={t1/t3:.1f}x")

    # Fig 11 analogue: latency delta of the host bounce (paper: 20-135us)
    emit("fig11_direct_vs_host_latency", (t2 - t3) * 1e6,
         f"saved_us={(t2-t3)*1e6:.0f}")


if __name__ == "__main__":
    main()
