"""Paper Fig. 10 analogue: DLRM ingest — streaming RDMA->device goodput.

Two sections:

**A. Streamed vs. synchronous ingest** (the PR 5 tentpole measurement).
The same record-aligned shard is fetched (i) with the synchronous
single-QP store-and-forward baseline (`fetch_shard`: block on the whole
READ, decode on the host, device_put) and (ii) with the streaming plane
(`fetch_shard_streaming`: striped across N replicas on concurrent QPs,
fragment tiles preprocessed on device the moment their bytes are
acknowledged).  Both run on identically bandwidth-shaped links
(1 pkt/tick per link), so goodput differences are pure pipeline
structure: QP fan-out + transport/compute overlap.  Reported per
replica count: goodput (bytes/tick), speedup over sync, overlap
efficiency (fraction of tile work hidden behind the wire).

**B. Kernel-path microbench** (the original Fig. 10 comparison):
host-CPU preprocessing vs. the fused on-path kernel with a host bounce
vs. direct-to-device.

``--smoke`` runs the small sweep + assertions only (the CI bench job);
``--json P`` writes all results to ``P``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from repro.core import telemetry as tm
from repro.core.ingest import (BalboaIngest, IngestConfig,
                               make_dlrm_tile_decoder)
from repro.core.services import PreprocService, ServiceChain
from repro.data import synthetic as syn

N_DENSE, N_SPARSE, MOD = 13, 26, 100_000
REC_W = N_DENSE + N_SPARSE
RPP = (4096 // 4) // REC_W
MTU = 4096


def _shard_fn(n_pkts):
    return lambda i: syn.encode_dlrm_packets(
        syn.dlrm_shard(i, RPP * n_pkts, N_DENSE, N_SPARSE))


def _decode_host(raw):
    """The host-side decode of the synchronous baseline — the copy the
    streaming plane exists to eliminate."""
    words = np.frombuffer(raw.tobytes(), np.int32).reshape(-1, MTU // 4)
    recs = words[:, :RPP * REC_W].reshape(-1, REC_W)
    dense = np.log1p(np.maximum(recs[:, :N_DENSE], 0).astype(np.float32))
    sparse = (recs[:, N_DENSE:] % MOD).astype(np.int32)
    return {"dense": dense, "sparse": sparse}


def sync_baseline(n_pkts: int) -> dict:
    """Single-QP store-and-forward fetch on a shaped link.

    Ticks are counted until the LAST BYTE lands (the same endpoint the
    streamed arm reports), not until `run_network`'s idle-detection
    tail, so the goodput comparison is like for like: wait for the
    whole transfer, then host-decode, then device_put."""
    from repro.core.rdma import step_network
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=1,
                     link_bw_pkts_per_tick=1),
        None, _shard_fn(n_pkts), decode_fn=_decode_host)
    nbytes = n_pkts * MTU
    qp, st = ing.qps[0], ing.storage[0]
    st.load_shard(st.node._qp_buffer[qp.qpn_r][1], 0)
    t0w, t0 = time.perf_counter(), ing.net.now
    ing.trainer.rdma_read(qp.qpn_l, nbytes)
    nodes = [ing.trainer, st.node]
    while ing.trainer.rx_progress(qp.qpn_l) < nbytes:
        step_network(nodes)
        assert ing.net.now - t0 < 100_000, "sync baseline stuck"
    ticks = ing.net.now - t0
    raw = ing.trainer._qp_buffer[qp.qpn_l][1][:nbytes]
    ing.host_payload_bytes += nbytes            # the store-and-forward copy
    batch = ing._to_device(_decode_host(raw.copy()))
    jax.block_until_ready(batch["dense"])
    return {"ticks": ticks, "nbytes": nbytes,
            "goodput": nbytes / max(ticks, 1),
            "wall_s": time.perf_counter() - t0w,
            "host_bytes": ing.host_payload_bytes}


def streamed(n_pkts: int, n_replicas: int, tile_pkts: int = 2,
             telemetry: bool = False, epoch_mode: str = None) -> dict:
    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=n_replicas,
                     link_bw_pkts_per_tick=1, tile_pkts=tile_pkts,
                     epoch_mode=epoch_mode),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    reg = None
    if telemetry:
        rec = tm.FlightRecorder(capacity=1 << 20)
        ing.attach_recorder(rec)
        reg = tm.MetricRegistry()
        tm.register_fabric(reg, ing.net)
        tm.register_node(reg, ing.trainer, "trainer")
        reg.register("ingest", ing.snapshot)
        tm.register_recorder(reg, rec)
    t0w = time.perf_counter()
    batch, rep = ing.fetch_shard_streaming(0)
    jax.block_until_ready(batch["dense"])
    out = {"ticks": rep.ticks, "nbytes": rep.nbytes,
           "goodput": rep.goodput_bytes_per_tick,
           "overlap": rep.overlap_efficiency,
           "tiles": rep.tiles, "stripes": len(rep.stripes),
           "wall_s": time.perf_counter() - t0w,
           "host_bytes": ing.host_payload_bytes}
    if reg is not None:
        snap = reg.snapshot()
        by = snap["flight"]["by_kind"]
        assert by.get("stream_tile", 0) == rep.tiles, \
            "stream_tile events != report tiles"
        out["telemetry"] = reg.flat(snap)
    return out


def ingest_sweep(smoke: bool) -> dict:
    n_pkts = 32 if smoke else 64
    replicas = (1, 4) if smoke else (1, 2, 4, 8)
    sync = sync_baseline(n_pkts)
    emit("fig10_sync_1qp", sync["ticks"],
         f"Bptick={sync['goodput']:.0f};host_bytes={sync['host_bytes']}")
    out = {"n_pkts": n_pkts, "sync": sync, "streamed": {}}
    for r in replicas:
        s = streamed(n_pkts, r, telemetry=(r == max(replicas)))
        out["streamed"][r] = s
        emit(f"fig10_stream_r{r}", s["ticks"],
             f"Bptick={s['goodput']:.0f};"
             f"vs_sync={s['goodput'] / sync['goodput']:.2f}x;"
             f"overlap={s['overlap']:.2f};host_bytes={s['host_bytes']}")
    # acceptance floor (ISSUE 5): at 4 replicas the streamed plane must
    # at least double the synchronous single-QP goodput, with more than
    # half the tile work hidden behind the transport — and no payload
    # byte may cross a host decode copy
    s4 = out["streamed"][4]
    speedup = s4["goodput"] / sync["goodput"]
    assert speedup >= 2.0, f"streamed/sync {speedup:.2f}x < 2x at 4 replicas"
    assert s4["overlap"] > 0.5, f"overlap {s4['overlap']:.2f} <= 0.5"
    assert s4["host_bytes"] == 0 and sync["host_bytes"] > 0
    out["speedup_4r"] = speedup
    # fused epoch arm: the same streamed fetch with the stream loop
    # advancing in watermark-bounded fused micro-epochs instead of
    # per-tick stepping — tick-visible results must be bit-identical
    # (delivered tiles, tick count, goodput, overlap); wall_s and the
    # telemetry blob are the only fields allowed to differ
    fr = max(replicas)
    f = streamed(n_pkts, fr, epoch_mode="fused")
    t = {k: out["streamed"][fr][k] for k in
         ("ticks", "nbytes", "goodput", "overlap", "tiles", "stripes")}
    ff = {k: f[k] for k in t}
    assert ff == t, f"fused ingest diverged from per-tick: {ff} vs {t}"
    out["streamed_fused"] = {fr: f}
    emit(f"fig10_stream_fused_r{fr}", f["ticks"],
         f"Bptick={f['goodput']:.0f};overlap={f['overlap']:.2f};"
         f"tick_wall_s={out['streamed'][fr]['wall_s']:.4f};"
         f"fused_wall_s={f['wall_s']:.4f}")
    return out


def kernel_path(total_mb: float = 8.0) -> dict:
    """Original Fig. 10 comparison on the kernel path alone."""
    n_pkts = int(total_mb * 1e6) // MTU
    n_rec = RPP * n_pkts
    raw = syn.dlrm_shard(0, n_rec, N_DENSE, N_SPARSE)
    pay = np.frombuffer(syn.encode_dlrm_packets(raw).tobytes(),
                        np.uint8).reshape(n_pkts, MTU)
    plen = jnp.asarray(np.full(n_pkts, MTU, np.int32))
    payj = jnp.asarray(pay)

    # ① vanilla: host-buffer copy + CPU preprocessing + device copy
    t0 = time.perf_counter()
    host_buf = np.asarray(payj).copy()                # DMA to host buffer
    recs = host_buf.reshape(n_pkts, -1)[:, :RPP * REC_W * 4]
    recs = recs.reshape(-1, REC_W * 4).view(np.int32)
    dense = np.log1p(np.maximum(recs[:, :N_DENSE], 0).astype(np.float32))
    sparse = recs[:, N_DENSE:] % MOD
    d = jax.device_put((dense, sparse))
    jax.block_until_ready(d)
    t1 = time.perf_counter() - t0
    emit("fig10_vanilla_cpu", t1 * 1e6, f"MBps={total_mb/t1:.1f}")

    # ② on-path preproc + host bounce
    chain = ServiceChain(on_path=[PreprocService(
        n_dense=N_DENSE, n_sparse=N_SPARSE, modulus=MOD)])
    chain.process(payj, plen)                         # compile
    t0 = time.perf_counter()
    out, _ = chain.process(payj, plen)
    host = np.asarray(out)                            # bounce to host
    d = jax.device_put(host)
    jax.block_until_ready(d)
    t2 = time.perf_counter() - t0
    emit("fig10_onpath_hostcopy", t2 * 1e6, f"MBps={total_mb/t2:.1f}")

    # ③ full BALBOA: on-path preproc, result stays on device
    t0 = time.perf_counter()
    out, _ = chain.process(payj, plen)
    jax.block_until_ready(out)
    t3 = time.perf_counter() - t0
    emit("fig10_balboa_direct", t3 * 1e6,
         f"MBps={total_mb/t3:.1f};vs_vanilla={t1/t3:.1f}x")

    # Fig 11 analogue: latency delta of the host bounce (paper: 20-135us)
    emit("fig11_direct_vs_host_latency", (t2 - t3) * 1e6,
         f"saved_us={(t2-t3)*1e6:.0f}")
    return {"vanilla_us": t1 * 1e6, "onpath_hostcopy_us": t2 * 1e6,
            "direct_us": t3 * 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + assertions only (CI bench job)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON")
    args = ap.parse_args(argv)

    results = {"mode": "smoke" if args.smoke else "full"}
    results["ingest"] = ingest_sweep(args.smoke)
    if not args.smoke:
        results["kernel_path"] = kernel_path()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
