"""Beyond-paper figure 11: allreduce bus bandwidth over the BALBOA
fabric, ring vs. in-fabric reduction offload.

The paper's headline pitch is line-rate compute on data as it arrives
from the network; the dominant data-center RDMA workload is the ML
collective.  This harness runs ring allreduce (reduce-scatter +
allgather over the verbs, every step through the batched engine /
retransmission / DCQCN pacing) and the offloaded variant (the switch-
resident ``SwitchReducer`` folds CHUNK contributions at the hop) on an
*identical* fabric, and reports the nccl-tests metric

    busbw = 2 (N-1)/N * bytes / ticks        [bytes per fabric tick]

Sweep axes: world size x message size x {ring, offload} x
{ack_clocked, dcqcn}.  The offloaded reduce phase is itself an incast —
N-1 flows converge on every owner port simultaneously — which is
exactly where the switch absorbing contributions before the drop-tail
queue pays off; the DCQCN arms run the same comparison with ECN marking
armed (``dcqcn_fabric_profile``).

Asserted (the PR's acceptance criteria):
  * at world=4 the offload achieves strictly higher bus bandwidth than
    the pure ring at equal fabric settings (every size, both CC arms);
  * every arm's output is bit-identical to ``allreduce_oracle`` — and
    the full sweep re-checks this on a *lossy* fabric arm (drops +
    retransmit).

``--smoke`` runs the tiny 4-node comparison only (the CI bench job);
``--json P`` writes all results to ``P`` for the bench trajectory.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks._util import emit
from repro.core import telemetry as tm
from repro.core.collectives import allreduce_oracle, make_ring_group
from repro.core.netsim import FabricConfig, dcqcn_fabric_profile

BASE_FABRIC = FabricConfig(port_bandwidth=4, port_delay=2,
                           queue_capacity=48, seed=7)
LOSSY_FABRIC = FabricConfig(port_bandwidth=4, port_delay=2,
                            queue_capacity=48, loss_prob=0.02, seed=5)


def _tensors(world: int, n_elems: int, seed: int = 13):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(n_elems).astype(np.float32)
            for _ in range(world)]


def allreduce_arm(world: int, n_elems: int, *, offload: bool,
                  cc: str = "ack_clocked", fabric_cfg=None,
                  telemetry: bool = False,
                  epoch_mode: str = None) -> dict:
    """One measured allreduce, output verified bit-identical to the
    oracle."""
    if fabric_cfg is None:
        fabric_cfg = dcqcn_fabric_profile() if cc == "dcqcn" else BASE_FABRIC
    g = make_ring_group(world, max_bytes=n_elems * 4 + world * 4,
                        fabric_cfg=fabric_cfg, offload=offload,
                        congestion_control=cc, epoch_mode=epoch_mode)
    reg = None
    if telemetry:
        rec = tm.FlightRecorder(capacity=1 << 20)
        g.attach_recorder(rec)
        reg = tm.MetricRegistry()
        tm.register_fabric(reg, g.net)
        reg.register("collective", g.snapshot)
        tm.register_recorder(reg, rec)
    xs = _tensors(world, n_elems)
    out = g.allreduce(xs)
    want = allreduce_oracle(xs)
    for r in range(world):
        assert (out[r].view(np.uint8) == want.view(np.uint8)).all(), \
            f"rank {r} not bit-identical to the oracle " \
            f"(world={world}, offload={offload}, cc={cc})"
    nbytes = n_elems * 4
    ticks = max(g.stats.ticks, 1)
    busbw = 2 * (world - 1) / world * nbytes / ticks
    res = {
        "world": world, "message_bytes": nbytes,
        "mode": "offload" if offload else "ring", "cc": cc,
        "lossy": fabric_cfg.loss_prob > 0,
        "ticks": ticks,
        "algbw_B_per_tick": round(nbytes / ticks, 2),
        "busbw_B_per_tick": round(busbw, 2),
        "retransmissions": sum(n.stats.retransmissions for n in g.nodes),
        "tail_dropped": g.net.total_tail_dropped,
    }
    if offload:
        red = g.service.reducer
        res.update(switch_absorbed=red.absorbed,
                   switch_forwarded=red.reduced_forwarded,
                   switch_acks=red.acks_synthesized,
                   switch_naks=red.naks_synthesized,
                   switch_peak_slots=red.peak_slots)
    if reg is not None:
        snap = reg.snapshot()
        by = snap["flight"]["by_kind"]
        assert by.get("coll_transfer", 0) == g.stats.transfers
        res["telemetry"] = reg.flat(snap)
    return res


def sweep(worlds=(2, 4, 8), sizes=(16_384, 262_144),
          ccs=("ack_clocked", "dcqcn"), check: bool = True) -> list:
    results = []
    for world in worlds:
        for n_elems in sizes:
            for cc in ccs:
                ring = allreduce_arm(world, n_elems, offload=False, cc=cc)
                off = allreduce_arm(world, n_elems, offload=True, cc=cc)
                results += [ring, off]
                gain = off["busbw_B_per_tick"] / ring["busbw_B_per_tick"]
                emit(f"fig11_allreduce_{world}n_{n_elems*4}B_{cc}", 0.0,
                     f"ring_busbw={ring['busbw_B_per_tick']};"
                     f"offload_busbw={off['busbw_B_per_tick']};"
                     f"gain={gain:.2f}x;ring_ticks={ring['ticks']};"
                     f"offload_ticks={off['ticks']}")
                if check and world == 4:
                    assert off["busbw_B_per_tick"] > \
                        ring["busbw_B_per_tick"], (
                            f"offload must beat the ring at 4 nodes "
                            f"({off['busbw_B_per_tick']} vs "
                            f"{ring['busbw_B_per_tick']}, cc={cc})")
    return results


def lossy_arm(world: int = 4, n_elems: int = 50_000) -> list:
    """Bit-identity under drops + retransmit, both modes (the acceptance
    property), measured on the same lossy fabric."""
    out = []
    for offload in (False, True):
        r = allreduce_arm(world, n_elems, offload=offload,
                          fabric_cfg=LOSSY_FABRIC)
        assert r["retransmissions"] > 0, "lossy arm saw no loss"
        out.append(r)
        emit(f"fig11_lossy_{r['mode']}", 0.0,
             f"busbw={r['busbw_B_per_tick']};retx={r['retransmissions']};"
             f"ticks={r['ticks']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny 4-node ring-vs-offload comparison only")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON to PATH")
    args = ap.parse_args(argv)

    results = {"mode": "smoke" if args.smoke else "full"}
    if args.smoke:
        results["allreduce"] = sweep(worlds=(4,), sizes=(16_384,),
                                     ccs=("ack_clocked",))
    else:
        results["allreduce"] = sweep()
        results["lossy"] = lossy_arm()
    results["instrumented"] = allreduce_arm(
        4, 16_384, offload=True, telemetry=True)
    # fused epoch arm: the software ring on the fused epoch driver must
    # report the same tick-visible metrics as per-tick stepping (the
    # allreduce output itself is already oracle-pinned inside the arm)
    tick = allreduce_arm(4, 16_384, offload=False)
    fused = allreduce_arm(4, 16_384, offload=False, epoch_mode="fused")
    keys = ("ticks", "busbw_B_per_tick", "retransmissions",
            "tail_dropped")
    assert {k: fused[k] for k in keys} == {k: tick[k] for k in keys}, \
        f"fused allreduce diverged from per-tick: {fused} vs {tick}"
    results["fused_epoch"] = {"tick": tick, "fused": fused}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
