"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (jax results blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV contract of benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
