"""Paper Table 2 analogue: per-component footprint.

LUT/BRAM/FF budgets have no TPU meaning; the equivalent budget here is
VMEM working set per kernel tile (BlockSpec-derived), parameter bytes,
and arithmetic intensity — the quantities that bound co-residency of
services with application offloads on one chip."""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.kernels import aes_ecb, crc32, dpi_mlp, preproc
from repro.kernels.ref import DPI_DIMS

VMEM_BYTES = 128 * 1024 * 1024     # v5e VMEM per core ~128 MiB (SMEM-adj.)


def main():
    rows = []
    # AES: tile (512,16) int32 in+out + round keys + tables
    aes_tile = aes_ecb.BLOCK_N * 16 * 4 * 2 + 11 * 16 * 4 + (256 + 16) * 4
    rows.append(("aes_ecb", aes_tile,
                 10 * 16 * aes_ecb.BLOCK_N * 4,      # ~rounds x bytes ops
                 "10 unrolled rounds; S-box gathers"))
    # CRC: tile (64, MTU) + 8x256 tables
    crc_tile = crc32.BLOCK_N * 4096 * 4 + 8 * 256 * 4 + crc32.BLOCK_N * 8
    rows.append(("crc32_icrc", crc_tile, 4096 // 8 * crc32.BLOCK_N * 12,
                 "slice-by-8; 3-path FPGA pipeline -> table gathers"))
    # DPI: beats tile + weights
    d_in, h1, h2 = DPI_DIMS
    w_bytes = d_in * h1 + h1 * h2 + h2
    dpi_tile = dpi_mlp.BLOCK_B * (d_in * 4 + 4) + w_bytes * 4
    flops = 2 * dpi_mlp.BLOCK_B * (d_in * h1 + h1 * h2 + h2)
    rows.append(("dpi_mlp", dpi_tile, flops,
                 f"ternary {d_in}-{h1}-{h2}-1; {w_bytes} weights"))
    # preproc: records tile
    pre_tile = preproc.BLOCK_M * 39 * 4 * 2
    rows.append(("dlrm_preproc", pre_tile, preproc.BLOCK_M * 39 * 4,
                 "neg2zero+log1p+modulus fused"))

    total = 0
    for name, vmem, flops, note in rows:
        total += vmem
        emit(f"table2_{name}", 0.0,
             f"vmem_tile_B={vmem};pct_vmem={100*vmem/VMEM_BYTES:.2f}%;"
             f"flops_per_tile={flops};{note}")
    emit("table2_total_services", 0.0,
         f"vmem_tile_B={total};pct_vmem={100*total/VMEM_BYTES:.2f}% — "
         f"paper: whole stack 3.4% LUTs, services add ~9%")


if __name__ == "__main__":
    main()
