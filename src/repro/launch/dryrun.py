import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import — jax locks
# the device count on first initialization (multi-pod dry-run contract).
#
# Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
# cell on the production meshes, print memory/cost analyses, and extract
# the roofline terms from the compiled HLO (repro.launch.hlo_analysis).
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
#   python -m repro.launch.dryrun --arch deepseek-v3-671b --shape decode_32k --multi-pod
#   python -m repro.launch.dryrun --all --json /tmp/dryrun.json

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.common.config import (LM_SHAPES, ModelConfig, SHAPES_BY_NAME,
                                 ShapeConfig, TrainConfig)
from repro.configs import ALL_ARCHS, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import analyze
from repro.models import params as P
from repro.models.model import ENC_LEN_FOR_DECODE, Model, input_specs
from repro.parallel import sharding as sh
from repro.train.step import make_decode_step, make_prefill_step, make_train_step


def _shardings_for(tree_shapes, tree_axes, mesh, rules, ctx):
    def one(s, a):
        return NamedSharding(mesh, sh.resolve_spec(s.shape, a, mesh, rules, ctx))
    return jax.tree.map(one, tree_shapes, tree_axes,
                        is_leaf=lambda t: isinstance(t, jax.ShapeDtypeStruct))


def _axes_is_leaf(t):
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in t)


def build_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   opt_override: Optional[Dict[str, Any]] = None):
    """Construct and lower the step function for one cell.  Everything is
    ShapeDtypeStructs — no array is ever allocated."""
    tc_kw = {}
    if opt_override:
        tc_kw = {k[3:]: v for k, v in opt_override.items()
                 if k.startswith("tc_")}
        opt_override = {k: v for k, v in opt_override.items()
                        if not k.startswith("tc_")}
        if opt_override:
            cfg = cfg.replace(**opt_override)
    model = Model(cfg)
    rules = sh.make_rules("train" if shape.kind == "train" else "serve",
                          long_context=(shape.name == "long_500k"))
    ctx = f"{cfg.name}/{shape.name}"

    pspec = model.param_spec()
    pshapes = P.shapes(pspec, cfg.param_dtype)
    paxes = P.axes(pspec)
    psh = _shardings_for(pshapes, paxes, mesh, rules, ctx)

    ispecs, iaxes = input_specs(cfg, shape)
    ish = _shardings_for(ispecs, iaxes, mesh, rules, ctx)
    repl = NamedSharding(mesh, PartitionSpec())

    with sh.activate(mesh, rules, ctx):
        if shape.kind == "train":
            tc = TrainConfig(**tc_kw)
            step_fn, opt = make_train_step(model, tc)
            ospec = opt.state_spec(pspec)
            oshapes = P.shapes(ospec, "float32")
            oaxes = P.axes(ospec)
            osh = _shardings_for(oshapes, oaxes, mesh, rules, ctx)
            step_shape = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step_fn,
                             in_shardings=(psh, osh, ish, repl),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, oshapes, ispecs, step_shape)
        else:
            enc_len = ENC_LEN_FOR_DECODE if (cfg.is_encdec and
                                             shape.is_decode) else (
                shape.seq_len if cfg.is_encdec else 0)
            cspec = model.cache_spec(shape.global_batch, shape.seq_len,
                                     enc_len)
            cshapes = P.shapes(cspec, cfg.compute_dtype)
            caxes = P.axes(cspec)
            csh = _shardings_for(cshapes, caxes, mesh, rules, ctx)
            if shape.kind == "prefill":
                step_fn = make_prefill_step(model)
                jitted = jax.jit(step_fn, in_shardings=(psh, ish, csh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(pshapes, ispecs, cshapes)
            else:  # decode
                step_fn = make_decode_step(model)
                tok_sh = ish["tokens"]
                tok_shape = ispecs["tokens"]
                jitted = jax.jit(step_fn,
                                 in_shardings=(psh, csh, tok_sh, repl),
                                 donate_argnums=(1,))
                lowered = jitted.lower(
                    pshapes, cshapes, tok_shape,
                    jax.ShapeDtypeStruct((), jnp.int32))
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_override: Optional[Dict[str, Any]] = None,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if shape_name in cfg.skip_shapes:
        result["status"] = "skip"
        result["reason"] = "see DESIGN.md §Arch-applicability"
        return result

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh_lib.n_chips(mesh)
    sh.clear_fallback_log()
    t0 = time.time()
    try:
        lowered = build_lowering(cfg, shape, mesh, opt_override)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:  # a failing cell is a bug in the system
        result["status"] = "FAIL"
        result["error"] = f"{type(e).__name__}: {e}"[:500]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} ({result['mesh']}): "
                  f"FAILED — {result['error']}", flush=True)
        return result

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax returns [dict, ...]
        cost = cost[0] if cost else {}
    hlo_cost = analyze(compiled.as_text(), chips)

    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "chips": chips,
        "xla_flops_per_device": float(cost.get("flops", -1.0)),
        "hlo_flops_per_device": hlo_cost.flops,
        "hlo_bytes_per_device": hlo_cost.bytes,
        "coll_traffic_per_device": hlo_cost.coll_traffic,
        "coll_breakdown": {k: v for k, v in sorted(
            hlo_cost.coll_bytes.items(), key=lambda kv: -kv[1])[:12]},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "sharding_fallbacks": sh.fallback_summary(),
    })
    # roofline terms (seconds) per device
    result["terms"] = {
        "compute_s": hlo_cost.flops / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": hlo_cost.bytes / mesh_lib.HBM_BW,
        "collective_s": hlo_cost.coll_traffic / mesh_lib.ICI_BW,
    }
    result["bottleneck"] = max(result["terms"], key=result["terms"].get)
    if verbose:
        t = result["terms"]
        print(f"[dryrun] {arch} x {shape_name} ({result['mesh']}): OK "
              f"compile={t_compile:.0f}s "
              f"compute={t['compute_s']*1e3:.2f}ms "
              f"memory={t['memory_s']*1e3:.2f}ms "
              f"coll={t['collective_s']*1e3:.2f}ms "
              f"-> {result['bottleneck']}", flush=True)
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB (per device)",
              flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ALL_ARCHS)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in LM_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on both meshes")
    ap.add_argument("--json", default=None, help="write results to file")
    args = ap.parse_args(argv)

    results = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in LM_SHAPES:
                for mp in (False, True):
                    results.append(run_cell(arch, shape.name, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            results.append(run_cell(args.arch, args.shape, mp))

    n_fail = sum(1 for r in results if r.get("status") == "FAIL")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} cells -> {args.json}")
    print(f"[dryrun] done: {len(results)} cells, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
