"""HLO text analyzer for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE —
with scan-over-layers everywhere, that undercounts a 61-layer model by
61x.  This module parses ``compiled.as_text()`` (the post-SPMD,
per-partition module), attributes per-computation costs through the call
graph, and multiplies while bodies by their trip count (recovered from
the loop-condition constant).

Extracted per (arch x shape x mesh) cell:
  * flops            — dot ops (2*M*N*K) + elementwise + reduces
  * bytes            — operand+result bytes of top-level instructions
                       (post-fusion: fusion internals are free, exactly
                       the memory-traffic model of a fused device)
  * collective bytes — per collective op kind, with ring-traffic factors
                       and replica-group sizes
All numbers are per-device (the module is one SPMD partition).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "sign", "floor", "ceil", "cosine", "sine", "select",
    "compare", "and", "or", "xor", "not", "clamp", "convert",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """Bytes and element count for a type string (maybe a tuple type)."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * DTYPE_BYTES[dt]
    # scalar like "f32[]" -> regex gives dims=""; handled (n=1).  Bare
    # scalars written as "f32[]" are covered; "s32[]" too.
    return total_b, total_e


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    args: str = ""          # raw text inside the op's parens


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]        # instr name -> type string


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operands: %names inside the top-level parens of rest
        depth = 0
        args_part = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            args_part.append(ch)
        args_str = "".join(args_part)
        operands = re.findall(r"%([\w\.\-_]+)", args_str)
        attrs = rest[len(args_str):]
        cur.instrs.append(Instr(name, type_str, opcode, operands, attrs,
                                args_str))
        cur.symbols[name] = type_str
    return comps


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_b, out_e = _shape_bytes_elems(instr.type_str)
    # contracted dims from the lhs operand shape
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    if not m or not instr.operands:
        return 2.0 * out_e            # fallback
    lhs_type = comp.symbols.get(instr.operands[0], "")
    shp = _SHAPE_RE.search(lhs_type)
    if not shp:
        return 2.0 * out_e
    dims = [int(d) for d in shp.group(2).split(",") if d]
    k = 1
    for ci in (int(c) for c in m.group(1).split(",") if c):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_e * k


def _group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(cond: Computation) -> int:
    """Heuristic: largest integer constant in the loop condition.

    A lax.scan lowers to a while whose condition is
    ``compare(induction_var, constant(T)), direction=LT`` — the trip
    count is that constant."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode != "constant":
            continue
        # constants parse as: %c = s32[] constant(61)
        m = re.search(r"(-?\d+)", ins.args)
        if m:
            best = max(best, int(m.group(1)))
    return max(best, 1)


_CALLED = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-_]+)")
_COND = re.compile(r"condition=%?([\w\.\-_]+)")

NO_TRAFFIC_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "iota", "after-all", "partition-id",
                  "replica-id", "while", "conditional", "call"}

# Ops whose traffic is NOT operands+result:
#  dynamic-slice: reads only the sliced window (= result), not the
#    operand — counting the full operand charges a 500k-entry KV cache
#    for every decode step's 1-token slice (x4096 inflation).
#  dynamic-update-slice / scatter: in-place read-modify-write of the
#    update region (donated buffers alias in XLA): 2x update bytes.
#  gather: result + index reads.
WINDOW_OPS = {"dynamic-slice": "result",
              "dynamic-update-slice": "update2",
              "scatter": "update2",
              "gather": "result",
              "select-and-scatter": "update2"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_traffic: float = 0.0      # ring-model per-device traffic

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_traffic += other.coll_traffic * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult


def _collective_traffic(kind: str, result_bytes: float, g: int) -> float:
    """Per-device ring-model traffic for one collective."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return result_bytes
    return result_bytes


class ModuleCost:
    def __init__(self, text: str, n_partitions: int = 1):
        self.comps = parse_module(text)
        self.n_partitions = n_partitions
        self._memo: Dict[str, Cost] = {}
        self._fusion_param_bytes: Dict[str, List[Optional[float]]] = {}
        entry = None
        for name, c in self.comps.items():
            if name.startswith("main") or entry is None:
                if entry is None or name.startswith("main"):
                    entry = name
        self.entry = entry

    def _param_window_bytes(self, comp_name: str) -> List[Optional[float]]:
        """For a fused computation: per-parameter effective read bytes.

        XLA fuses ``dynamic-slice``/``gather`` into consumers, so a scan
        body's tiny fusion can name the whole carried xs array as an
        operand while only touching one slice.  A parameter whose every
        consumer is a slicing op is charged the slice results, not the
        full array.  None = charge full operand bytes."""
        if comp_name in self._fusion_param_bytes:
            return self._fusion_param_bytes[comp_name]
        comp = self.comps.get(comp_name)
        out: List[Optional[float]] = []
        if comp is None:
            self._fusion_param_bytes[comp_name] = out
            return out
        params: Dict[str, int] = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                m = re.search(r"(\d+)", ins.args)
                if m:
                    params[ins.name] = int(m.group(1))
        n_params = (max(params.values()) + 1) if params else 0
        out = [None] * n_params
        sliced: Dict[str, float] = {}
        full: set = set()
        for ins in comp.instrs:
            for op_name in ins.operands:
                if op_name not in params:
                    continue
                if ins.opcode in ("dynamic-slice", "gather"):
                    b, _ = _shape_bytes_elems(ins.type_str)
                    sliced[op_name] = sliced.get(op_name, 0.0) + b
                elif ins.opcode in ("dynamic-update-slice",):
                    # in-place update: the buffer param is read only in
                    # the update window (write side counted at the
                    # fusion result)
                    ub = 0
                    if len(ins.operands) >= 2:
                        ub, _ = _shape_bytes_elems(
                            comp.symbols.get(ins.operands[1], ""))
                    sliced[op_name] = sliced.get(op_name, 0.0) + ub
                else:
                    full.add(op_name)
        for pname, idx in params.items():
            if pname in sliced and pname not in full:
                out[idx] = sliced[pname]
        self._fusion_param_bytes[comp_name] = out
        return out

    def _fusion_write_bytes(self, comp_name: str, default: float) -> float:
        """Write traffic of a fusion: a DUS-rooted fusion writes only the
        update window of its (aliased, donated) buffer, not the whole
        result shape."""
        comp = self.comps.get(comp_name)
        if comp is None or not comp.instrs:
            return default
        root = comp.instrs[-1]
        if root.opcode == "dynamic-update-slice" and len(root.operands) >= 2:
            ub, _ = _shape_bytes_elems(
                comp.symbols.get(root.operands[1], ""))
            if ub:
                return float(ub)
        return default

    def cost(self) -> Cost:
        return self._comp_cost(self.entry, top_level=True)

    def _comp_cost(self, name: str, top_level: bool = False) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[name] = total  # provisional (cycles shouldn't occur)
        for ins in comp.instrs:
            opc = ins.opcode
            if opc == "while":
                body = _CALLED.search(ins.attrs)
                cond = _COND.search(ins.attrs)
                trips = 1
                if cond and cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond.group(1)])
                if body:
                    total.add(self._comp_cost(body.group(1)), trips)
                continue
            if opc in ("fusion", "call", "async-start", "custom-call"):
                called = _CALLED.search(ins.attrs)
                if called:
                    sub = self._comp_cost(called.group(1))
                    # flops inside the fusion count; bytes are the fusion's
                    # own operands/results (added below)
                    total.flops += sub.flops
                    total.coll_traffic += sub.coll_traffic
                    for k, v in sub.coll_bytes.items():
                        total.coll_bytes[k] = total.coll_bytes.get(k, 0) + v
            if opc == "conditional":
                for called in _CALLED.findall(ins.attrs):
                    total.add(self._comp_cost(called), 1.0)
            # ---- flops ------------------------------------------------
            if opc == "dot":
                total.flops += _dot_flops(ins, comp)
            elif opc in ELEMENTWISE:
                _, e = _shape_bytes_elems(ins.type_str)
                total.flops += e
            elif opc == "reduce":
                for op_name in ins.operands[:1]:
                    _, e = _shape_bytes_elems(comp.symbols.get(op_name, ""))
                    total.flops += e
            # ---- bytes (memory traffic model: post-fusion boundaries) --
            if opc in WINDOW_OPS:
                b, _ = _shape_bytes_elems(ins.type_str)
                mode = WINDOW_OPS[opc]
                if mode == "result":
                    total.bytes += 2 * b          # read window + write result
                else:  # update2: RMW of the update region
                    ub = 0
                    if len(ins.operands) >= 2:
                        ub, _ = _shape_bytes_elems(
                            comp.symbols.get(ins.operands[1], ""))
                    total.bytes += 2 * max(ub, 1) if ub else 2 * b
            elif opc == "fusion":
                b, _ = _shape_bytes_elems(ins.type_str)
                called = _CALLED.search(ins.attrs)
                windows = (self._param_window_bytes(called.group(1))
                           if called else [])
                if called:
                    b = min(b, self._fusion_write_bytes(called.group(1), b))
                ob = 0.0
                for i, op_name in enumerate(ins.operands):
                    w = windows[i] if i < len(windows) else None
                    if w is not None:
                        ob += w
                    else:
                        o, _ = _shape_bytes_elems(
                            comp.symbols.get(op_name, ""))
                        ob += o
                total.bytes += b + ob
            elif opc not in NO_TRAFFIC_OPS:
                b, _ = _shape_bytes_elems(ins.type_str)
                ob = 0
                for op_name in ins.operands:
                    o, _ = _shape_bytes_elems(comp.symbols.get(op_name, ""))
                    ob += o
                total.bytes += b + ob
            # ---- collectives -------------------------------------------
            for coll in COLLECTIVES:
                if opc == coll or opc == coll + "-start":
                    rb, _ = _shape_bytes_elems(ins.type_str)
                    g = _group_size(ins.attrs, self.n_partitions)
                    key = f"{coll}(g={g})"
                    total.coll_bytes[key] = total.coll_bytes.get(key, 0) + rb
                    total.coll_traffic += _collective_traffic(coll, rb, g)
        self._memo[name] = total
        return total


def analyze(text: str, n_partitions: int) -> Cost:
    return ModuleCost(text, n_partitions).cost()


def top_bytes(text: str, n_partitions: int, k: int = 20):
    """Debug: top-k instructions by attributed bytes (incl. trip mult)."""
    mc = ModuleCost(text, n_partitions)
    mc.cost()                       # fill memo
    # recompute per-instruction contributions with multipliers
    mults: Dict[str, float] = {mc.entry: 1.0}
    order = [mc.entry]
    # propagate multipliers down the call graph (BFS)
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = mc.comps.get(name)
        if comp is None:
            continue
        m = mults[name]
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _CALLED.search(ins.attrs)
                cond = _COND.search(ins.attrs)
                trips = _trip_count(mc.comps[cond.group(1)]) if cond and \
                    cond.group(1) in mc.comps else 1
                if body:
                    mults[body.group(1)] = mults.get(body.group(1), 0) + m * trips
                    order.append(body.group(1))
            elif ins.opcode in ("call", "conditional"):
                for called in _CALLED.findall(ins.attrs):
                    mults[called] = mults.get(called, 0) + m
                    order.append(called)
    rows = []
    for name, m in mults.items():
        comp = mc.comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            opc = ins.opcode
            b = 0.0
            if opc in WINDOW_OPS:
                rb, _ = _shape_bytes_elems(ins.type_str)
                mode = WINDOW_OPS[opc]
                if mode == "result":
                    b = 2 * rb
                else:
                    ub = 0
                    if len(ins.operands) >= 2:
                        ub, _ = _shape_bytes_elems(
                            comp.symbols.get(ins.operands[1], ""))
                    b = 2 * max(ub, 1) if ub else 2 * rb
            elif opc == "fusion":
                rb, _ = _shape_bytes_elems(ins.type_str)
                called = _CALLED.search(ins.attrs)
                windows = (mc._param_window_bytes(called.group(1))
                           if called else [])
                if called:
                    rb = min(rb, mc._fusion_write_bytes(called.group(1), rb))
                ob = 0.0
                for i, o in enumerate(ins.operands):
                    w = windows[i] if i < len(windows) else None
                    ob += w if w is not None else _shape_bytes_elems(
                        comp.symbols.get(o, ""))[0]
                b = rb + ob
            elif opc not in NO_TRAFFIC_OPS and opc != "call":
                rb, _ = _shape_bytes_elems(ins.type_str)
                ob = sum(_shape_bytes_elems(comp.symbols.get(o, ""))[0]
                         for o in ins.operands)
                b = rb + ob
            if b:
                rows.append((b * m, f"{opc} {ins.type_str[:60]} x{m:.0f} "
                             f"in {name[:40]}"))
    rows.sort(key=lambda x: -x[0])
    return rows[:k]
