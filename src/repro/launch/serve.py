"""Serving launcher: batched prefill + decode with the KV-cache runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models.model import ENC_LEN_FOR_DECODE, Model
from repro.train.step import make_decode_step, make_prefill_step


def serve_batch(cfg, model, batch_size: int, prompt_len: int, gen: int,
                seed: int = 0):
    params = model.init_params(jax.random.key(seed))
    prompts = jax.random.randint(jax.random.key(seed + 1),
                                 (batch_size, prompt_len), 0, cfg.vocab)
    enc_len = 16 if cfg.is_encdec else 0
    cache = model.init_cache(jax.random.key(2), batch_size,
                             prompt_len + gen, enc_len=enc_len)
    pre = {"tokens": prompts}
    if cfg.is_encdec:
        pre["audio_embed"] = jax.random.normal(
            jax.random.key(3), (batch_size, enc_len, cfg.d_model))
    if cfg.vision_stub:
        pre["vision_embed"] = jnp.zeros(
            (batch_size, prompt_len, cfg.d_model))
        pre["vision_mask"] = jnp.zeros((batch_size, prompt_len), jnp.int32)
        pre["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32)[None, None],
            (3, batch_size, prompt_len))
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, pre, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        tok, cache = decode(params, cache, tok,
                            jnp.asarray(prompt_len + t, jnp.int32))
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, t_prefill, t_decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    tokens, t_p, t_d = serve_batch(cfg, model, args.batch, args.prompt_len,
                                   args.gen)
    n_tok = tokens.shape[0] * tokens.shape[1]
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prefill={t_p*1e3:.1f}ms decode={t_d*1e3:.1f}ms "
          f"({n_tok/(t_d+1e-9):.0f} tok/s)")
    print(f"[serve] sample tokens: {tokens[0][:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
