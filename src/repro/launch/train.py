"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --smoke --steps 100 --batch 8 --seq 128

``--smoke`` selects the reduced config (runs on this container); without
it the full config is used (sized for the production mesh — lower it via
repro.launch.dryrun instead of running here)."""
from __future__ import annotations

import argparse

import jax

from repro.common.config import TrainConfig
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.parallel import sharding as sh
from repro.train.loop import Trainer, lm_batch_iterator


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=("none", "bf16"))
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(steps=args.steps, learning_rate=args.lr,
                     microbatches=args.microbatches,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every,
                     pod_grad_compression=args.compression)
    mesh = make_host_mesh(data=len(jax.devices()))
    model = Model(cfg)
    trainer = Trainer(model, tc, mesh=mesh)
    res = trainer.run(lm_batch_iterator(cfg, args.batch, args.seq))
    print(f"[train] done: {res.steps_run} steps, "
          f"loss {res.losses[0]:.4f} -> {res.final_loss:.4f}, "
          f"{res.wall_s:.1f}s"
          + (f" (resumed from {res.resumed_from})" if res.resumed_from
             else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
