"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state.  The single-pod mesh is a TPU v5e-256 pod
(16 x 16); the multi-pod mesh stacks 2 pods (2 x 16 x 16 = 512 chips)
with the ``pod`` axis crossing the DCI/RDMA domain — exactly the link
layer RoCE BALBOA serves.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over real local devices (examples / tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
