"""Logical-axis sharding with divisibility-checked fallback.

Model code never names mesh axes directly; it tags array dimensions with
*logical* names ("batch", "heads", "d_ff", "expert", ...).  A rule table
maps each logical name to an ordered list of candidate mesh-axis tuples;
resolution picks the first candidate whose axes (a) exist in the mesh,
(b) are not already used by another dimension of the same array, and
(c) evenly divide the dimension.  Anything that cannot shard falls back
to replication and is recorded in ``FALLBACK_LOG`` so the dry-run report
can show exactly what got replicated and why.

This is what makes all 40 (arch x shape) cells lower on both the
single-pod (16,16) and the multi-pod (2,16,16) mesh without per-arch
hand-tuning.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = Optional[Tuple[str, ...]]
Rules = dict  # logical name -> tuple of Candidate, tried in order


def _c(*names) -> Tuple[Candidate, ...]:
    """Helper: each arg is either a tuple of mesh axes or None."""
    out = []
    for n in names:
        if n is None:
            out.append(None)
        elif isinstance(n, str):
            out.append((n,))
        else:
            out.append(tuple(n))
    return tuple(out)


# ---------------------------------------------------------------------------
# Rule tables.  "pod" exists only on the multi-pod mesh; candidates naming it
# are skipped automatically on the single-pod mesh.
# ---------------------------------------------------------------------------

# Training: DP(+pod) over batch, FSDP over the embed dim of weights along
# "data", TP over heads / d_ff / vocab along "model", EP over "data".
TRAIN_RULES: Rules = {
    "batch":    _c(("pod", "data"), "data", None),
    "seq":      _c(None),
    "kv_seq":   _c(None),
    "embed":    _c("data", None),          # FSDP shard dim of weights
    "embed_tp": _c("model", None),         # activation d_model when TP'd
    "d_model":  _c(None),                  # activation d_model (replicated)
    "heads":    _c("model", None),
    "kv_heads": _c("model", None),
    "head_dim": _c(None),
    "d_ff":     _c("model", None),
    "vocab":    _c("model", None),
    "expert":   _c("data", None),          # EP: experts over data
    "expert2d": _c(("data", "model"), "data", None),  # EP over both axes
    "expert_ff": _c("model", None),        # TP inside each expert
    "expert_rows": _c("data", None),       # dispatch rows (one per data shard)
    "lru":      _c("model", None),
    "layers":   _c(None),
    "lora":     _c(None),
    "stack":    _c(None),
}

# Decode / prefill: batch over data(+pod); weights TP over "model" only —
# serving keeps dense/attn weights REPLICATED over "data" because
# FSDP-style sharding re-all-gathers every parameter on every decode step
# (measured: 6.3 GiB/device/token on gemma2-27b, the dominant decode
# collective; see EXPERIMENTS.md §Perf).  Expert weights stay EP-sharded
# over "data" via the separate "expert" axis.  KV cache: batch over data,
# heads over model; long-context shards the cache sequence instead.
SERVE_RULES: Rules = dict(TRAIN_RULES)
SERVE_RULES.update({
    "batch":    _c(("pod", "data"), "data", None),
    "kv_seq":   _c(None),
    "cache_seq": _c(None),       # overridden to ("model",) for long_500k
    "expert":   _c("data", None),
    "embed":    _c(None),
})

LONG_CONTEXT_OVERRIDES = {
    # batch=1: nothing to DP over -> shard the KV cache sequence instead.
    "cache_seq": _c("model", None),
    "kv_seq":    _c(None),
    "batch":     _c(None),
}


def make_rules(kind: str, *, long_context: bool = False) -> Rules:
    rules = dict(TRAIN_RULES if kind == "train" else SERVE_RULES)
    if long_context:
        rules.update(LONG_CONTEXT_OVERRIDES)
    return rules


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

FALLBACK_LOG: list = []  # (context, dim_name, dim_size, candidate, reason)


class _Active(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None
        self.context: str = ""


_ACTIVE = _Active()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Rules, context: str = ""):
    """Make (mesh, rules) visible to ``constrain`` inside model code."""
    prev = (_ACTIVE.mesh, _ACTIVE.rules, _ACTIVE.context)
    _ACTIVE.mesh, _ACTIVE.rules, _ACTIVE.context = mesh, rules, context
    try:
        yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules, _ACTIVE.context = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE.mesh


def resolve_spec(
    dims: Sequence[int],
    names: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Rules,
    context: str = "",
) -> PartitionSpec:
    """Resolve logical dimension names to a PartitionSpec for ``mesh``."""
    assert len(dims) == len(names), (dims, names)
    used: set = set()
    spec = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(dims, names):
        chosen: Candidate = None
        if name is not None:
            for cand in rules.get(name, (None,)):
                if cand is None:
                    chosen = None
                    break
                if any(a not in axis_sizes for a in cand):
                    continue            # axis absent on this mesh (e.g. "pod")
                if any(a in used for a in cand):
                    continue            # axis already used by another dim
                size = 1
                for a in cand:
                    size *= axis_sizes[a]
                if dim % size != 0:
                    FALLBACK_LOG.append((context, name, dim, cand, "indivisible"))
                    continue
                chosen = cand
                break
        if chosen is None:
            spec.append(None)
        else:
            used.update(chosen)
            spec.append(chosen if len(chosen) > 1 else chosen[0])
    return PartitionSpec(*spec)


def named_sharding(
    dims: Sequence[int],
    names: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
    context: str = "",
) -> Optional[NamedSharding]:
    mesh = mesh or _ACTIVE.mesh
    rules = rules or _ACTIVE.rules
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, resolve_spec(dims, names, mesh, rules, context))


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` via logical names; no-op without a mesh."""
    if _ACTIVE.mesh is None or _ACTIVE.rules is None:
        return x
    spec = resolve_spec(x.shape, names, _ACTIVE.mesh, _ACTIVE.rules, _ACTIVE.context)
    sh = NamedSharding(_ACTIVE.mesh, spec)
    return jax.lax.with_sharding_constraint(x, sh)


def tree_shardings(shape_tree, axes_tree, mesh, rules, context: str = ""):
    """NamedSharding tree for a pytree of ShapeDtypeStructs + axes tuples."""
    return jax.tree.map(
        lambda s, a: NamedSharding(
            mesh, resolve_spec(s.shape, a, mesh, rules, context)
        ),
        shape_tree,
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t
        ),
    )


def clear_fallback_log():
    FALLBACK_LOG.clear()


def fallback_summary() -> str:
    if not FALLBACK_LOG:
        return "no sharding fallbacks"
    lines = []
    seen = set()
    for ctx, name, dim, cand, reason in FALLBACK_LOG:
        key = (ctx, name, dim, cand)
        if key in seen:
            continue
        seen.add(key)
        lines.append(f"  [{ctx}] {name}={dim} !-> {cand} ({reason})")
    return "sharding fallbacks:\n" + "\n".join(lines)
