"""Sharded checkpointing with elastic restore.

Layout: <dir>/step_<N>/
           manifest.json        — tree structure, shapes, dtypes, step
           arrays.npz           — flat leaf arrays (host-gathered)

Design points for 1000+-node operation (scaled to this container):
  * writes go to a temp dir + atomic rename — a failure mid-write never
    corrupts the latest checkpoint;
  * ``restore`` re-device_puts against *whatever mesh is active now* —
    elastic: a job restarted on a different pod count resumes from the
    same file (resharding happens at load);
  * async save: the host copy is snapshotted synchronously (cheap), the
    file write happens on a background thread so the train loop keeps
    stepping (overlap with compute).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    out = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype.kind not in "fiub":          # e.g. bfloat16 (kind 'V'):
            a = a.astype(np.float32)            # no npz codec; restore() casts
        out[f"leaf_{i}"] = a
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Dict[str, Any], blocking: bool = False):
        """state: arbitrary pytree dict (params, opt_state, rng, ...)."""
        arrays, treedef = _flatten(state)
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(arrays)}
        self.wait()
        t = threading.Thread(target=self._write, args=(step, arrays, manifest))
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def _write(self, step: int, arrays, manifest):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any], step: Optional[int] = None,
                shardings=None) -> Tuple[int, Dict[str, Any]]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for the *current* mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves_like, treedef = jax.tree.flatten(like)
        assert len(leaves_like) == len(data.files), \
            f"checkpoint has {len(data.files)} leaves, expected {len(leaves_like)}"
        sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                     else [None] * len(leaves_like))
        out = []
        for i, (ref, shd) in enumerate(zip(leaves_like, sh_leaves)):
            arr = data[f"leaf_{i}"]
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.device_put(arr))
        return step, jax.tree.unflatten(treedef, out)
