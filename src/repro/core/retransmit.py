"""Retransmission buffer and transport timer (paper §4.2).

All transmitted payloads are held in a dedicated buffer ("directly
exposed HBM channel" on the FPGA) until the remote end acknowledges
reception; timeouts or NAKs (PSN sequence errors) release them back onto
the TX path without another host round-trip.

FPGA -> TPU design dual: the FPGA parks payloads in HBM and replays
them from hardware timers; the dual keeps a per-QP PSN-keyed dict of
held packets on the host (retransmission is the rare path — it only
runs when the simulated network loses or reorders, so it stays off the
jitted hot path) with the same cumulative-ACK release, go-back-N NAK
replay and exponential-backoff timer semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import packet as pk


@dataclasses.dataclass
class _Slot:
    psn: int
    packet: pk.Packet
    deadline: int          # retransmit when now >= deadline
    retries: int = 0


class RetransmissionBuffer:
    """Per-QP ring of unacked packets, keyed by PSN."""

    MAX_RETRIES = 16

    def __init__(self, timeout_ticks: int = 64, capacity: int = 4096):
        self.timeout = timeout_ticks
        self.capacity = capacity
        self.slots: Dict[int, Dict[int, _Slot]] = {}   # qpn -> psn -> slot
        self.retransmissions = 0
        self.exhausted: List[Tuple[int, int]] = []     # fatal (qpn, psn)

    def hold(self, qpn: int, p: pk.Packet, now: int):
        q = self.slots.setdefault(qpn, {})
        if len(q) >= self.capacity:
            raise RuntimeError(f"retransmission buffer overflow qp={qpn}")
        q[p.psn] = _Slot(p.psn, p.clone(), now + self.timeout)

    def ack(self, qpn: int, ack_psn: int) -> int:
        """Cumulative ACK: release every slot with psn <= ack_psn
        (mod-24-bit window).  Returns number released.

        Progress resets the retry counters of the remaining slots —
        RoCE's retry budget counts *consecutive* no-progress events, not
        total retransmissions (go-back-N would otherwise burn the budget
        of healthy flows)."""
        q = self.slots.get(qpn, {})
        released = [s for s in q
                    if ((ack_psn - s) % (pk.PSN_MASK + 1)) <= pk.PSN_MASK // 2]
        for s in released:
            del q[s]
        if released:
            for slot in q.values():
                slot.retries = 0
        return len(released)

    def nak(self, qpn: int, expected_psn: int, now: int) -> List[pk.Packet]:
        """PSN sequence error at the peer: retransmit from expected_psn."""
        return self._resend(qpn, expected_psn, now)

    def sack_release(self, qpn: int, ack_psn: int, sack_bits: int) -> int:
        """Selective ACK: release the individually-acknowledged slots a
        selective-repeat receiver reports holding out of order (bitmap
        bit k => PSN ``ack_psn + 1 + k`` received).  Returns the number
        released.  Like cumulative progress, a selective release resets
        the remaining slots' retry counters — the peer demonstrably got
        packets, so the flow is not stuck."""
        q = self.slots.get(qpn, {})
        released = 0
        k = 1                        # bit 0 (= ack_psn + 1 in sequence)
        bits = sack_bits >> 1        # would be a cumulative advance
        while bits:
            if bits & 1:
                psn = (ack_psn + 1 + k) & pk.PSN_MASK
                if q.pop(psn, None) is not None:
                    released += 1
            bits >>= 1
            k += 1
        if released:
            for slot in q.values():
                slot.retries = 0
        return released

    def gap_resend(self, qpn: int, ack_psn: int, upto_psn: int,
                   min_lag: int, now: int) -> List[pk.Packet]:
        """Selective-repeat fast retransmit: resend only the *gaps* — the
        held slots strictly after the cumulative ACK but at least
        ``min_lag`` PSNs behind ``upto_psn`` (the highest PSN the
        receiver's SACK proves delivered).  The lag guard keeps plain
        multipath reorder (fast-spine packets overtaking slow-spine
        ones) from triggering spurious resends; a real loss keeps
        falling further behind the SACK frontier until it crosses the
        threshold."""
        span = pk.PSN_MASK + 1
        q = self.slots.get(qpn, {})
        out = []
        for slot in sorted(q.values(), key=lambda s: s.psn):
            after_ack = 0 < ((slot.psn - ack_psn) % span) <= pk.PSN_MASK // 2
            lag = (upto_psn - slot.psn) % span
            if after_ack and lag <= pk.PSN_MASK // 2 and lag >= min_lag:
                out.extend(self._bump(qpn, slot, now))
        return out

    def tick(self, now: int) -> List[Tuple[int, pk.Packet]]:
        """Transport timer: collect timed-out (local_qpn, packet) pairs.
        Slots that exhausted their retry budget are evicted (fatal for
        the flow — surfaced via ``self.exhausted`` so the upper layer
        can tear down / re-establish the QP)."""
        out = []
        # sorted: replay order must not depend on dict insertion
        # history (reestablish_qp pops and re-adds a QP's slot map)
        for qpn in sorted(self.slots):
            q = self.slots[qpn]
            dead = []
            for slot in sorted(q.values(), key=lambda s: s.psn):
                if now >= slot.deadline:
                    resend = self._bump(qpn, slot, now)
                    if not resend and slot.retries > self.MAX_RETRIES:
                        dead.append(slot.psn)
                    out.extend((qpn, p) for p in resend)
            for psn in dead:
                q.pop(psn, None)
        return out

    def _resend(self, qpn: int, from_psn: int, now: int) -> List[pk.Packet]:
        q = self.slots.get(qpn, {})
        out = []
        for slot in sorted(q.values(), key=lambda s: s.psn):
            behind = ((slot.psn - from_psn) % (pk.PSN_MASK + 1)) \
                <= pk.PSN_MASK // 2
            if behind:
                out.extend(self._bump(qpn, slot, now))
        return out

    def _bump(self, qpn: int, slot: _Slot, now: int) -> List[pk.Packet]:
        slot.retries += 1
        if slot.retries > self.MAX_RETRIES:
            self.exhausted.append((qpn, slot.psn))
            return []
        slot.deadline = now + self.timeout * (1 << min(slot.retries, 4))
        self.retransmissions += 1
        return [slot.packet.clone()]

    def outstanding(self, qpn: int) -> int:
        return len(self.slots.get(qpn, {}))

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"retransmissions": self.retransmissions,
                "exhausted": len(self.exhausted),
                "held": sum(len(q) for q in self.slots.values())}
