"""RoCE v2 packet model (paper §4.1).

Packets follow the RoCE v2 header stack (IP / UDP / InfiniBand BTH /
RETH).  Opcode values follow the InfiniBand RC opcode space.

FPGA -> TPU design dual: the FPGA parses one 512-bit header beat per
cycle through pipelined FSMs; the dual represents a *batch* of packets
as a dict of arrays (one column per header field, payloads padded to
MTU) so the RX/TX pipelines in ``repro.core.pipeline`` and the service
chain are SIMD across packets instead of across clock cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

# --- IB RC opcodes (subset BALBOA implements: one-sided ops + ACK) --------
WRITE_FIRST = 0x06
WRITE_MIDDLE = 0x07
WRITE_LAST = 0x08
WRITE_ONLY = 0x0A
READ_REQUEST = 0x0C
READ_RESP_FIRST = 0x0D
READ_RESP_MIDDLE = 0x0E
READ_RESP_LAST = 0x0F
READ_RESP_ONLY = 0x10
ACK = 0x11
NAK = 0x31          # we fold the NAK syndrome into its own opcode
NAK_PROT = 0x32     # NAK, remote-access (R_Key) protection error: fatal,
                    # the requester must not retry (IB "Remote Access Error")
CNP = 0x81          # RoCE v2 congestion notification packet (DCQCN NP->RP)

OPCODE_NAMES = {
    WRITE_FIRST: "WRITE_FIRST", WRITE_MIDDLE: "WRITE_MIDDLE",
    WRITE_LAST: "WRITE_LAST", WRITE_ONLY: "WRITE_ONLY",
    READ_REQUEST: "READ_REQUEST", READ_RESP_FIRST: "READ_RESP_FIRST",
    READ_RESP_MIDDLE: "READ_RESP_MIDDLE", READ_RESP_LAST: "READ_RESP_LAST",
    READ_RESP_ONLY: "READ_RESP_ONLY", ACK: "ACK", NAK: "NAK",
    NAK_PROT: "NAK_PROT", CNP: "CNP",
}

WRITE_OPS = (WRITE_FIRST, WRITE_MIDDLE, WRITE_LAST, WRITE_ONLY)
READ_RESP_OPS = (READ_RESP_FIRST, READ_RESP_MIDDLE, READ_RESP_LAST,
                 READ_RESP_ONLY)
# opcodes that carry an address (start of a new DMA region).  NOTE: on
# the wire, READ RESPONSEs carry no RETH (the requester tracks its
# scatter address); our simulator attaches the base address to the first
# response fragment instead of a per-QP pending-read table — same
# semantics, recorded as a simplification in DESIGN.md.
RETH_OPS = (WRITE_FIRST, WRITE_ONLY, READ_REQUEST, READ_RESP_FIRST,
            READ_RESP_ONLY)
# opcodes that carry payload
PAYLOAD_OPS = WRITE_OPS + READ_RESP_OPS

MTU = 4096                      # paper §6: MTU set to 4K
UDP_DPORT_ROCE = 4791           # RoCE v2 well-known UDP port
PSN_MASK = 0x00FF_FFFF          # 24-bit PSN space


@dataclasses.dataclass
class Packet:
    """One RoCE v2 packet (host-side representation)."""
    # IP / UDP
    src_ip: int = 0
    dst_ip: int = 0
    src_port: int = 0
    dst_port: int = UDP_DPORT_ROCE
    # BTH
    opcode: int = ACK
    qpn: int = 0
    psn: int = 0
    ack_req: bool = False
    # RETH (valid for RETH_OPS)
    vaddr: int = 0
    rkey: int = 0
    dma_len: int = 0
    # AETH-ish (for ACK/NAK): cumulative PSN being acknowledged
    ack_psn: int = 0
    msn: int = 0
    # Selective-ACK bitmap (selective-repeat RX mode): bit k set means
    # PSN ``ack_psn + 1 + k`` was received out of order (bit 0 — the
    # expected PSN itself — is never set: receiving it advances the
    # cumulative ACK instead).  0 on go-back-N ACKs and on data packets.
    sack_bits: int = 0
    # Multipath routing tag: the spine index a leaf-spine fabric carried
    # (or should carry) this packet over.  Stamped by spraying/ECMP
    # senders, honored and/or (re)stamped by ``netsim.ClosFabric``,
    # echoed into CNPs so per-path DCQCN can cut the congested path
    # only.  -1 = unrouted / single-path fabric.
    path_id: int = -1
    # payload
    payload: Optional[np.ndarray] = None      # uint8[<=MTU]
    icrc: int = 0
    # DPI decision flag travels with the host-directed command (§5.1.2)
    dpi_flag: bool = False
    # IP ECN field: True = Congestion Experienced (CE).  Set by the
    # switch when an egress queue crosses its Kmin/Kmax marking
    # thresholds; echoed by the receiver as a CNP (DCQCN NP role).
    ecn: bool = False
    # Collective CHUNK tag (in-fabric reduction offload).  ``coll_tag``
    # != 0 marks this payload packet as one contribution to a switch-
    # resident reduction slot; the fabric's SwitchReducer absorbs the
    # contribution (synthesizing the transport ACK itself) instead of
    # forwarding it, and releases one summed packet per fragment once
    # all ``coll_nsrc`` contributors delivered it.  ``coll_src`` is the
    # contributor's position in the canonical fold order (NOT its rank;
    # position coll_nsrc-1 is the *carrier* whose packets survive the
    # hop and deliver the sums).  ``coll_frag`` indexes the MTU-sized
    # fragment within the chunk, so slots reduce fragment-wise.
    coll_tag: int = 0
    coll_src: int = -1
    coll_nsrc: int = 0
    coll_frag: int = -1

    @property
    def payload_len(self) -> int:
        return 0 if self.payload is None else int(self.payload.size)

    def clone(self) -> "Packet":
        p = dataclasses.replace(self)
        if self.payload is not None:
            p.payload = self.payload.copy()
        return p


def batch_from_packets(pkts, mtu: int = MTU) -> Dict[str, np.ndarray]:
    """Pack a list of Packets into a dict-of-arrays batch for the
    vectorized (jax) pipelines.  Payloads are padded to ``mtu``."""
    n = len(pkts)
    out = {
        "opcode": np.zeros(n, np.int32),
        "qpn": np.zeros(n, np.int32),
        "psn": np.zeros(n, np.int32),
        "ack_req": np.zeros(n, np.int32),
        "vaddr": np.zeros(n, np.int64),
        "rkey": np.zeros(n, np.int32),
        "dma_len": np.zeros(n, np.int32),
        "ack_psn": np.zeros(n, np.int32),
        "ecn": np.zeros(n, np.int32),
        "plen": np.zeros(n, np.int32),
        "payload": np.zeros((n, mtu), np.uint8),
        "valid": np.ones(n, np.int32),
    }
    for i, p in enumerate(pkts):
        out["opcode"][i] = p.opcode
        out["qpn"][i] = p.qpn
        out["psn"][i] = p.psn
        out["ack_req"][i] = int(p.ack_req)
        out["vaddr"][i] = p.vaddr
        out["rkey"][i] = p.rkey
        out["dma_len"][i] = p.dma_len
        out["ack_psn"][i] = p.ack_psn
        out["ecn"][i] = int(p.ecn)
        if p.payload is not None:
            out["plen"][i] = p.payload.size
            out["payload"][i, :p.payload.size] = p.payload
    return out


def fragment_message(
    qpn: int, start_psn: int, vaddr: int, rkey: int, data: np.ndarray,
    *, op: str = "write", mtu: int = MTU, src_ip: int = 0, dst_ip: int = 0,
    coll: Optional[tuple] = None, addr_per_pkt: bool = False,
):
    """Fragment one RDMA WRITE (or READ RESPONSE) payload into MTU-sized
    packets with FIRST/MIDDLE/LAST/ONLY opcodes, consecutive PSNs and a
    RETH on the first packet (paper §4.1 TX path).

    ``coll = (tag, src, nsrc, frag_base)`` stamps every fragment as a
    collective CHUNK contribution (fragment indices continue from
    ``frag_base``, so one chunk split into several flow-control
    sub-messages still numbers its fragments globally).

    ``addr_per_pkt=True`` makes every fragment self-contained (IRN
    style, for selective-repeat receivers): each packet carries its own
    target address / rkey / length, so an out-of-order arrival can DMA
    without the FIRST fragment's RETH cursor."""
    assert op in ("write", "read_resp")
    data = np.asarray(data, np.uint8)
    n_pkts = max(1, (data.size + mtu - 1) // mtu)
    tag, src, nsrc, frag_base = coll if coll is not None else (0, -1, 0, 0)
    pkts = []
    for i in range(n_pkts):
        chunk = data[i * mtu:(i + 1) * mtu]
        if n_pkts == 1:
            opc = WRITE_ONLY if op == "write" else READ_RESP_ONLY
        elif i == 0:
            opc = WRITE_FIRST if op == "write" else READ_RESP_FIRST
        elif i == n_pkts - 1:
            opc = WRITE_LAST if op == "write" else READ_RESP_LAST
        else:
            opc = WRITE_MIDDLE if op == "write" else READ_RESP_MIDDLE
        if addr_per_pkt:
            p_vaddr, p_rkey, p_len = vaddr + i * mtu, rkey, chunk.size
        else:
            p_vaddr = vaddr if i == 0 else 0
            p_rkey = rkey if i == 0 else 0
            p_len = data.size if i == 0 else 0
        pkts.append(Packet(
            src_ip=src_ip, dst_ip=dst_ip, opcode=opc, qpn=qpn,
            psn=(start_psn + i) & PSN_MASK, ack_req=(i == n_pkts - 1),
            vaddr=p_vaddr, rkey=p_rkey,
            dma_len=p_len, payload=chunk.copy(),
            coll_tag=tag, coll_src=src, coll_nsrc=nsrc,
            coll_frag=(frag_base + i) if tag else -1))
    return pkts


def make_read_request(qpn: int, psn: int, vaddr: int, rkey: int,
                      length: int, src_ip: int = 0, dst_ip: int = 0) -> Packet:
    return Packet(src_ip=src_ip, dst_ip=dst_ip, opcode=READ_REQUEST,
                  qpn=qpn, psn=psn & PSN_MASK, vaddr=vaddr, rkey=rkey,
                  dma_len=length, ack_req=True)


def make_ack(qpn: int, ack_psn: int, msn: int = 0, nak: bool = False,
             sack: int = 0) -> Packet:
    """ACK/NAK with optional selective-ACK bitmap (``sack`` bit k =>
    PSN ``ack_psn + 1 + k`` held out of order by a selective-repeat
    receiver)."""
    return Packet(opcode=NAK if nak else ACK, qpn=qpn,
                  psn=ack_psn & PSN_MASK, ack_psn=ack_psn & PSN_MASK,
                  msn=msn, sack_bits=int(sack))


def make_nak_prot(qpn: int, psn: int = 0) -> Packet:
    """Remote-access protection NAK: the wire rkey did not match the
    registered buffer's rkey.  Fatal for the flow — the requester marks
    the QP errored instead of retrying (retries can never succeed)."""
    return Packet(opcode=NAK_PROT, qpn=qpn, psn=psn & PSN_MASK)


def make_cnp(qpn: int, src_ip: int = 0, dst_ip: int = 0,
             path_id: int = -1) -> Packet:
    """Congestion notification (DCQCN NP -> RP).  Pure control signal:
    carries no PSN/AETH state on purpose — a CNP must never advance
    cumulative-ACK state at the reaction point.  ``path_id`` echoes the
    spine the CE-marked packet crossed, so a multipath reaction point
    can cut the congested path's rate instead of the whole QP's."""
    return Packet(opcode=CNP, qpn=qpn, src_ip=src_ip, dst_ip=dst_ip,
                  path_id=path_id)


def read_resp_npkts(length: int, mtu: int = MTU) -> int:
    return max(1, (length + mtu - 1) // mtu)
