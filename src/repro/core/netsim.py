"""Deterministic lossy-network simulator.

The dry-run container has no NIC; the *protocol logic* of BALBOA is
exercised against this simulator instead: configurable loss probability,
reordering, latency (in integer ticks) and bandwidth shaping.  Tests
drive full sender -> network -> RX-pipeline -> ACK -> retransmit loops
and assert exactly-once in-order delivery of every byte.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import packet as pk


@dataclasses.dataclass
class LinkConfig:
    loss_prob: float = 0.0
    reorder_prob: float = 0.0
    latency_ticks: int = 4
    jitter_ticks: int = 0
    bandwidth_pkts_per_tick: int = 0     # 0 = unshaped
    seed: int = 0


class Link:
    """One direction of a network path."""

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._heap: List[Tuple[int, int, pk.Packet]] = []
        self._seq = 0
        self.sent = 0
        self.dropped = 0

    def send(self, p: pk.Packet, now: int):
        self.sent += 1
        if self.rng.random() < self.cfg.loss_prob:
            self.dropped += 1
            return
        delay = self.cfg.latency_ticks
        if self.cfg.jitter_ticks:
            delay += int(self.rng.integers(0, self.cfg.jitter_ticks + 1))
        if self.rng.random() < self.cfg.reorder_prob:
            delay += int(self.rng.integers(1, 8))
        self._seq += 1
        heapq.heappush(self._heap, (now + delay, self._seq, p))

    def deliver(self, now: int) -> List[pk.Packet]:
        out = []
        budget = self.cfg.bandwidth_pkts_per_tick or 1 << 30
        while self._heap and self._heap[0][0] <= now and budget > 0:
            _, _, p = heapq.heappop(self._heap)
            out.append(p)
            budget -= 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._heap)


class Network:
    """A set of nodes connected pairwise by two directed links."""

    def __init__(self, n_nodes: int, cfg: LinkConfig = LinkConfig()):
        self.links: Dict[Tuple[int, int], Link] = {}
        for a in range(n_nodes):
            for b in range(n_nodes):
                if a != b:
                    c = dataclasses.replace(cfg, seed=cfg.seed * 1000 + a * 37 + b)
                    self.links[(a, b)] = Link(c)
        self.now = 0

    def send(self, src: int, dst: int, p: pk.Packet):
        self.links[(src, dst)].send(p, self.now)

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        self.now += 1
        return {k: l.deliver(self.now) for k, l in self.links.items()
                if l.in_flight}

    def quiescent(self) -> bool:
        return all(l.in_flight == 0 for l in self.links.values())
