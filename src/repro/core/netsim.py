"""Deterministic network simulator: point-to-point links and a switched
fabric.

FPGA -> TPU design dual: the paper evaluates BALBOA on a physical 100G
testbed behind a data-center switch; the dry-run container has no NIC,
so the *protocol logic* is exercised against this simulator instead.
Time is integer ticks; every random decision is seeded, so whole
sender -> network -> RX-pipeline -> ACK -> retransmit loops replay
bit-identically (which is what lets tests assert exactly-once in-order
delivery and lets the batched engine be diffed against the scan oracle
on the very same trace).

Two topologies:

``Network``        — nodes connected pairwise by two directed ``Link``s
                     (loss, reorder, latency, jitter, bandwidth shaping).
                     The original point-to-point model.
``SwitchedFabric`` — a single-switch star: every node hangs off one
                     switch port.  Packets traverse the ingress wire
                     (per-port delay, optional loss), land in the
                     *shared egress queue* of the destination port
                     (drop-tail, finite capacity) and drain at the
                     port's bandwidth.  This is where incast lives: N
                     senders converging on one receiver overflow that
                     receiver's egress queue exactly like a real
                     shallow-buffered ToR switch.

Both expose the same surface (``send`` / ``tick`` / ``quiescent`` /
``now``) so ``RdmaNode`` and ``run_network`` work with either.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import packet as pk


@dataclasses.dataclass
class LinkConfig:
    loss_prob: float = 0.0
    reorder_prob: float = 0.0
    latency_ticks: int = 4
    jitter_ticks: int = 0
    bandwidth_pkts_per_tick: int = 0     # 0 = unshaped
    seed: int = 0


class Link:
    """One direction of a network path."""

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._heap: List[Tuple[int, int, pk.Packet]] = []
        self._seq = 0
        self.sent = 0
        self.dropped = 0

    def send(self, p: pk.Packet, now: int):
        self.sent += 1
        if self.rng.random() < self.cfg.loss_prob:
            self.dropped += 1
            return
        delay = self.cfg.latency_ticks
        if self.cfg.jitter_ticks:
            delay += int(self.rng.integers(0, self.cfg.jitter_ticks + 1))
        if self.rng.random() < self.cfg.reorder_prob:
            delay += int(self.rng.integers(1, 8))
        self._seq += 1
        heapq.heappush(self._heap, (now + delay, self._seq, p))

    def deliver(self, now: int) -> List[pk.Packet]:
        out = []
        budget = self.cfg.bandwidth_pkts_per_tick or 1 << 30
        while self._heap and self._heap[0][0] <= now and budget > 0:
            _, _, p = heapq.heappop(self._heap)
            out.append(p)
            budget -= 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._heap)


class Network:
    """A set of nodes connected pairwise by two directed links."""

    def __init__(self, n_nodes: int, cfg: LinkConfig = LinkConfig()):
        self.links: Dict[Tuple[int, int], Link] = {}
        for a in range(n_nodes):
            for b in range(n_nodes):
                if a != b:
                    c = dataclasses.replace(cfg, seed=cfg.seed * 1000 + a * 37 + b)
                    self.links[(a, b)] = Link(c)
        self.now = 0

    def send(self, src: int, dst: int, p: pk.Packet):
        self.links[(src, dst)].send(p, self.now)

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        self.now += 1
        return {k: l.deliver(self.now) for k, l in self.links.items()
                if l.in_flight}

    def quiescent(self) -> bool:
        return all(l.in_flight == 0 for l in self.links.values())


# ---------------------------------------------------------------------------
# Switched fabric
# ---------------------------------------------------------------------------

def _per_port(value: Union[int, Sequence[int]], n_ports: int) -> List[int]:
    """Broadcast a scalar config to all ports, or validate a sequence."""
    if isinstance(value, (list, tuple)):
        if len(value) != n_ports:
            raise ValueError(f"per-port config of length {len(value)} "
                             f"for {n_ports} ports")
        return [int(v) for v in value]
    return [int(value)] * n_ports


@dataclasses.dataclass
class FabricConfig:
    """Single-switch star fabric.  ``port_bandwidth`` and ``port_delay``
    accept either a scalar (all ports alike) or a per-port sequence."""
    port_bandwidth: Union[int, Sequence[int]] = 4   # egress pkts per tick
    port_delay: Union[int, Sequence[int]] = 2       # ingress wire latency
    queue_capacity: int = 64                        # egress drop-tail depth
    loss_prob: float = 0.0                          # random wire loss
    seed: int = 0


@dataclasses.dataclass
class PortStats:
    enqueued: int = 0
    delivered: int = 0
    tail_dropped: int = 0        # drop-tail at the egress queue
    wire_dropped: int = 0        # random loss on the ingress wire
    max_depth: int = 0           # high-water mark of the egress queue


class SwitchedFabric:
    """A single switch; node ``i`` hangs off port ``i``.

    Datapath per packet: ingress wire (``port_delay[src]`` ticks, seeded
    random loss) -> destination port's egress FIFO (drop-tail at
    ``queue_capacity``) -> drained at ``port_bandwidth[dst]`` packets
    per tick.  The egress queue is *shared by all flows* targeting that
    port — congestion (incast) shows up as drop-tail losses the RDMA
    layer must recover via retransmission, exactly like a
    shallow-buffered data-center switch.
    """

    def __init__(self, n_nodes: int, cfg: Optional[FabricConfig] = None):
        cfg = cfg if cfg is not None else FabricConfig()
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.bandwidth = _per_port(cfg.port_bandwidth, n_nodes)
        self.delay = _per_port(cfg.port_delay, n_nodes)
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0
        self._seq = 0
        # packets on the ingress wire: (arrival_tick, seq, dst, packet)
        self._wire: List[Tuple[int, int, int, pk.Packet]] = []
        self.egress: List[Deque[pk.Packet]] = [
            collections.deque() for _ in range(n_nodes)]
        self.port_stats = [PortStats() for _ in range(n_nodes)]

    def send(self, src: int, dst: int, p: pk.Packet):
        st = self.port_stats[dst]
        if self.cfg.loss_prob and self.rng.random() < self.cfg.loss_prob:
            st.wire_dropped += 1
            return
        self._seq += 1
        heapq.heappush(self._wire,
                       (self.now + self.delay[src], self._seq, dst, p))

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        """Advance one tick: move arrived packets into egress queues
        (drop-tail), then drain each port at its bandwidth.  Returns
        ``{(-1, dst): packets}`` — the switch is the source."""
        self.now += 1
        while self._wire and self._wire[0][0] <= self.now:
            _, _, dst, p = heapq.heappop(self._wire)
            q = self.egress[dst]
            st = self.port_stats[dst]
            if len(q) >= self.cfg.queue_capacity:
                st.tail_dropped += 1
                continue
            q.append(p)
            st.enqueued += 1
            st.max_depth = max(st.max_depth, len(q))
        out: Dict[Tuple[int, int], List[pk.Packet]] = {}
        for dst in range(self.n_nodes):
            q = self.egress[dst]
            if not q:
                continue
            batch = [q.popleft()
                     for _ in range(min(self.bandwidth[dst], len(q)))]
            self.port_stats[dst].delivered += len(batch)
            out[(-1, dst)] = batch
        return out

    def quiescent(self) -> bool:
        return not self._wire and all(not q for q in self.egress)

    # ---- telemetry ----------------------------------------------------
    @property
    def total_tail_dropped(self) -> int:
        return sum(s.tail_dropped for s in self.port_stats)

    @property
    def total_delivered(self) -> int:
        return sum(s.delivered for s in self.port_stats)


@dataclasses.dataclass
class IncastResult:
    receiver: object                  # RdmaNode (port 0, the hot port)
    senders: List[object]             # RdmaNode per sender
    fabric: SwitchedFabric
    ticks: int                        # simulated ticks until quiescent
    payloads: List[np.ndarray]        # what sender i wrote (QPN i+1 at rx)


def incast_scenario(n_senders: int, *, message_bytes: int = 65536,
                    fabric_cfg: Optional[FabricConfig] = None,
                    rx_credits: int = 64, fc_window: int = 16,
                    max_ticks: int = 300_000,
                    engine: str = "batched") -> IncastResult:
    """The canonical congestion scenario: ``n_senders`` nodes RDMA-WRITE
    simultaneously into one receiver through a shallow-buffered switch
    port.  Runs until the fabric drains — callers assert delivery and
    inspect drop/retransmit stats.
    """
    from repro.core.rdma import RdmaNode, run_network   # cycle-free import

    cfg = fabric_cfg or FabricConfig(port_bandwidth=4, port_delay=2,
                                     queue_capacity=32, seed=7)
    fabric = SwitchedFabric(n_senders + 1, cfg)
    recv = RdmaNode(0, fabric, rx_credits=rx_credits, engine=engine)
    senders = [RdmaNode(i + 1, fabric, fc_window=fc_window, engine=engine)
               for i in range(n_senders)]
    rng = np.random.default_rng(13)
    work = []
    for s in senders:
        qpn, _, _ = s.init_rdma(message_bytes, recv)
        data = rng.integers(0, 256, message_bytes, dtype=np.uint8)
        work.append((s, qpn, data))
    for s, qpn, data in work:
        s.rdma_write(qpn, data)
    ticks = run_network([recv] + senders, max_ticks=max_ticks)
    return IncastResult(receiver=recv, senders=senders, fabric=fabric,
                        ticks=ticks, payloads=[d for _, _, d in work])
