"""Deterministic network simulator: point-to-point links and a switched
fabric.

FPGA -> TPU design dual: the paper evaluates BALBOA on a physical 100G
testbed behind a data-center switch; the dry-run container has no NIC,
so the *protocol logic* is exercised against this simulator instead.
Time is integer ticks; every random decision is seeded, so whole
sender -> network -> RX-pipeline -> ACK -> retransmit loops replay
bit-identically (which is what lets tests assert exactly-once in-order
delivery and lets the batched engine be diffed against the scan oracle
on the very same trace).

Three topologies:

``Network``        — nodes connected pairwise by two directed ``Link``s
                     (loss, reorder, latency, jitter, bandwidth shaping).
                     The original point-to-point model.
``SwitchedFabric`` — a single-switch star: every node hangs off one
                     switch port.  Packets traverse the ingress wire
                     (per-port delay, optional loss), land in the
                     *shared egress queue* of the destination port
                     (drop-tail, finite capacity) and drain at the
                     port's bandwidth.  This is where incast lives: N
                     senders converging on one receiver overflow that
                     receiver's egress queue exactly like a real
                     shallow-buffered ToR switch.  With ``ecn_kmin`` /
                     ``ecn_kmax`` configured, the switch additionally
                     plays the DCQCN congestion-point role: packets are
                     CE-marked (RED-style, at dequeue) instead of only
                     tail-dropped, feeding the CNP/rate-control loop in
                     ``flow_control`` / ``rdma``.
``ClosFabric``     — a two-tier leaf-spine (Clos) fabric: nodes hang
                     off leaf switches, leaves interconnect through
                     ``n_spines`` parallel spine planes.  Cross-leaf
                     packets pick a spine per flow (ECMP hash) or per
                     packet (spray), so the fabric genuinely delivers
                     out of order when spine delays are asymmetric —
                     the arrival pattern selective-repeat RX exists
                     for.  Every stage reuses the same drop-tail /
                     RED-marking egress machinery as the single
                     switch, and a spine can be failed mid-run.

All expose the same surface (``send`` / ``tick`` / ``quiescent`` /
``now``) so ``RdmaNode`` and ``run_network`` work with any of them.

The switched fabric can additionally host a ``SwitchReducer`` (the
in-fabric reduction offload of ``repro.core.collectives``): CHUNK-
tagged packets are folded at the hop instead of forwarded, with the
switch playing a full go-back-N responder toward the contributors.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import chaos as _chaos
from repro.core import packet as pk


@dataclasses.dataclass
class LinkConfig:
    loss_prob: float = 0.0
    reorder_prob: float = 0.0
    latency_ticks: int = 4
    jitter_ticks: int = 0
    bandwidth_pkts_per_tick: int = 0     # 0 = unshaped
    seed: int = 0
    # chaos mode: when set, loss / jitter / reorder decisions come from
    # the counter-keyed hash in ``repro.core.chaos`` instead of the rng
    # stream — replayable inside the fused epoch core (``core.fused``).
    chaos_seed: Optional[int] = None


class Link:
    """One direction of a network path."""

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._heap: List[Tuple[int, int, pk.Packet]] = []
        self._seq = 0
        self.sent = 0
        self.dropped = 0
        self.on_event = None     # flight-recorder hook: (kind, packet)
        self._ctick = -1         # chaos mode: per-tick send rank
        self._cidx = 0

    def send(self, p: pk.Packet, now: int):
        self.sent += 1
        if self.cfg.chaos_seed is not None:
            return self._send_chaos(p, now)
        if self.rng.random() < self.cfg.loss_prob:
            self.dropped += 1
            if self.on_event is not None:
                self.on_event("wire_drop", p)
            return
        if self.on_event is not None:
            self.on_event("inject", p)
        delay = self.cfg.latency_ticks
        if self.cfg.jitter_ticks:
            delay += int(self.rng.integers(0, self.cfg.jitter_ticks + 1))
        if self.rng.random() < self.cfg.reorder_prob:
            delay += int(self.rng.integers(1, 8))
        self._seq += 1
        heapq.heappush(self._heap, (now + delay, self._seq, p))

    def _send_chaos(self, p: pk.Packet, now: int):
        """Counter-keyed decisions: every send on this link takes the
        next rank within its tick; each decision hashes (seed, purpose,
        tick, rank) independently — the exact stream ``core.fused``
        replays in-graph."""
        if now != self._ctick:
            self._ctick, self._cidx = now, 0
        i, s = self._cidx, self.cfg.chaos_seed
        self._cidx += 1
        if self.cfg.loss_prob and _chaos.hash32(
                s, _chaos.TAG_LOSS, now, i) < _chaos.u32_prob(
                    self.cfg.loss_prob):
            self.dropped += 1
            if self.on_event is not None:
                self.on_event("wire_drop", p)
            return
        if self.on_event is not None:
            self.on_event("inject", p)
        delay = self.cfg.latency_ticks
        if self.cfg.jitter_ticks:
            delay += _chaos.hash32(s, _chaos.TAG_JITTER, now, i) \
                % (self.cfg.jitter_ticks + 1)
        if self.cfg.reorder_prob and _chaos.hash32(
                s, _chaos.TAG_REORDER, now, i) < _chaos.u32_prob(
                    self.cfg.reorder_prob):
            delay += 1 + _chaos.hash32(s, _chaos.TAG_RDELAY, now, i) % 7
        self._seq += 1
        heapq.heappush(self._heap, (now + delay, self._seq, p))

    def deliver(self, now: int) -> List[pk.Packet]:
        out = []
        budget = self.cfg.bandwidth_pkts_per_tick or 1 << 30
        while self._heap and self._heap[0][0] <= now and budget > 0:
            _, _, p = heapq.heappop(self._heap)
            out.append(p)
            budget -= 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._heap)


class Network:
    """A set of nodes connected pairwise by two directed links."""

    def __init__(self, n_nodes: int, cfg: LinkConfig = LinkConfig()):
        self.links: Dict[Tuple[int, int], Link] = {}
        for a in range(n_nodes):
            for b in range(n_nodes):
                if a != b:
                    c = dataclasses.replace(
                        cfg, seed=cfg.seed * 1000 + a * 37 + b,
                        chaos_seed=None if cfg.chaos_seed is None else
                        _chaos.link_stream(cfg.chaos_seed, a, b))
                    self.links[(a, b)] = Link(c)
        self.now = 0
        self.recorder = None

    def send(self, src: int, dst: int, p: pk.Packet):
        self.links[(src, dst)].send(p, self.now)

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        self.now += 1
        return {k: l.deliver(self.now) for k, l in self.links.items()
                if l.in_flight}

    def quiescent(self) -> bool:
        return all(l.in_flight == 0 for l in self.links.values())

    # ---- telemetry ----------------------------------------------------
    def attach_recorder(self, rec):
        """Record per-link inject / wire_drop lifecycle events into a
        ``telemetry.FlightRecorder`` (track per directed link)."""
        self.recorder = rec

        def hook(track):
            def on_event(kind, p):
                rec.record(self.now, kind, track, qpn=p.qpn, psn=p.psn)
            return on_event

        for (a, b), link in self.links.items():
            link.on_event = hook(("link", f"{a}->{b}"))

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"now": self.now,
                "injected": sum(l.sent for l in self.links.values()),
                "wire_dropped": sum(l.dropped for l in self.links.values()),
                "in_flight": sum(l.in_flight for l in self.links.values())}


# ---------------------------------------------------------------------------
# Switched fabric (+ the in-fabric reduction offload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ReduceSlot:
    """One in-flight reduction: (coll_tag, coll_frag) -> contributions."""
    nsrc: int
    dst: int
    contribs: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    carrier: Optional[pk.Packet] = None     # held until the slot completes
    done_payload: Optional[np.ndarray] = None


class SwitchReducer:
    """Switch-resident reduction engine (the in-fabric half of the
    collective offload; control-plane handle: ``collectives.
    AllreduceService``).

    The paper's thesis is line-rate compute on data *as it arrives from
    the network*; this is that idea moved one hop upstream, onto the
    switch the fabric models (SHARP / SwitchML lineage, expressed in
    BALBOA vocabulary).  Architecturally it is a parallel-path service
    tap placed at the congestion point: CHUNK-tagged packets (``Packet.
    coll_*``) are diverted off the forwarding path as they leave the
    ingress wire, their payloads accumulate in per-(tag, fragment)
    slots, and once all ``coll_nsrc`` contributors delivered a fragment
    ONE summed packet enters the egress queue — the N:1 incast of a
    direct reduction never touches the drop-tail buffer.

    Transport invariants are preserved, not bypassed:

      * the switch plays a full go-back-N *responder* per contributor
        stream (fragment-granular: in-sequence contributions are
        absorbed and ACKed, gaps are NAKed, late retransmissions are
        re-ACKed) — per-packet ACKs alone would be wrong, because the
        sender's release is cumulative and an ACK for fragment k+1
        would silently free a lost fragment k that nobody could ever
        resend;
      * the **carrier** (fold position ``nsrc - 1``) is never absorbed:
        its packets are held and forwarded with payloads replaced by
        the fold result, so the destination sees one ordinary in-order
        WRITE stream — PSN checking, rkey protection, crediting and
        completion generation all run unchanged;
      * retransmissions dedup against the slot (re-ACKed, never
        double-summed); a carrier retransmission after completion is
        re-filled from the cached result, so losses *behind* the switch
        recover end-to-end exactly like any other loss.

    The fold runs in canonical contribution order (``coll_src`` IS the
    fold position) via ``reduce_fn`` — the jitted segmented-reduce
    kernel — which is what keeps ring and offloaded collectives
    bit-identical.
    """

    def __init__(self, reduce_fn):
        self.reduce_fn = reduce_fn          # (K, L) u8 -> (L,) u8, row order
        self._slots: Dict[Tuple[int, int], _ReduceSlot] = {}
        # per-tag forwarding cursor: completed fragments are released to
        # the egress queue IN ORDER, so a loss-induced completion gap
        # never shows the destination an out-of-order carrier PSN (the
        # resulting NAK storm would burn the carrier's retry budget on
        # resends the incomplete slot cannot serve yet)
        self._next_fwd: Dict[int, int] = {}
        # per-(tag, fold position) responder cursor: next fragment
        # expected in sequence from that contributor stream
        self._next_frag: Dict[Tuple[int, int], int] = {}
        # control plane: (src node, dst node) -> the contributor's local
        # QPN, installed by the collective group at setup so synthesized
        # ACKs address the right sender-side QP
        self._ack_qpn: Dict[Tuple[int, int], int] = {}
        # telemetry
        self.absorbed = 0            # contributions summed at the hop
        self.acks_synthesized = 0
        self.naks_synthesized = 0    # go-back-N NAKs for stream gaps
        self.reduced_forwarded = 0   # summed packets released to egress
        self.dup_dropped = 0
        self.refills = 0             # carrier retransmits after completion
        self.peak_slots = 0
        self.bytes_reduced = 0

    def register_qp(self, src_node: int, dst_node: int, src_qpn: int):
        self._ack_qpn[(src_node, dst_node)] = src_qpn

    def clear(self):
        """Drop completed-slot caches (safe once the fabric is
        quiescent — between collective operations)."""
        self._slots.clear()
        self._next_fwd.clear()
        self._next_frag.clear()

    @property
    def in_flight(self) -> int:
        """Held carrier packets (awaiting completion or in-order
        release) — in-flight work the fabric must not call quiescent."""
        return sum(s.carrier is not None for s in self._slots.values())

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"absorbed": self.absorbed,
                "acks_synthesized": self.acks_synthesized,
                "naks_synthesized": self.naks_synthesized,
                "reduced_forwarded": self.reduced_forwarded,
                "dup_dropped": self.dup_dropped,
                "refills": self.refills,
                "peak_slots": self.peak_slots,
                "bytes_reduced": self.bytes_reduced,
                "in_flight": self.in_flight}

    # ---- datapath ----------------------------------------------------
    def on_packet(self, dst: int, p: pk.Packet
                  ) -> List[Tuple[int, pk.Packet]]:
        """Process one CHUNK-tagged arrival.  Returns ``(port, packet)``
        pairs to enqueue (summed forwards toward ``dst``, synthesized
        ACKs/NAKs back toward contributors); the contribution itself
        never reaches an egress queue."""
        tag, frag, pos = p.coll_tag, p.coll_frag, p.coll_src
        is_carrier = pos == p.coll_nsrc - 1
        nxt = self._next_frag.get((tag, pos), 0)

        if frag > nxt:
            # sequence gap in this contributor stream (an earlier
            # fragment was lost on the wire): go-back-N, exactly like a
            # receiving endpoint — dropping + NAKing is what keeps the
            # sender's cumulative-ACK release sound
            self.naks_synthesized += 1
            return self._nak(p, dst, nxt)

        if frag < nxt:                         # retransmission from behind
            self.dup_dropped += 1
            if not is_carrier:
                # the earlier ACK was lost; re-ACK at boundaries only
                # (cumulative release covers the rest, as at an endpoint)
                return self._ack(p, dst) if p.ack_req else []
            slot = self._slots.get((tag, frag))
            if (slot is not None and slot.done_payload is not None
                    and frag < self._next_fwd.get(tag, 0)):
                # the summed forward was lost behind the switch: re-fill
                # from the cached fold and send it again
                self.refills += 1
                return [(dst, self._filled(p, slot.done_payload))]
            return []                          # held / queued: nothing to do

        # in sequence: absorb the contribution
        self._next_frag[(tag, pos)] = nxt + 1
        slot = self._slots.get((tag, frag))
        if slot is None:
            slot = self._slots[(tag, frag)] = _ReduceSlot(
                nsrc=p.coll_nsrc, dst=dst)
            self.peak_slots = max(self.peak_slots, len(self._slots))
        slot.contribs[pos] = np.asarray(p.payload, np.uint8).copy()
        out: List[Tuple[int, pk.Packet]] = []
        if is_carrier:
            slot.carrier = p                   # held, forwarded on completion
        else:
            self.absorbed += 1
            if p.ack_req:
                # ACK like an endpoint: only at sub-message boundaries,
                # releasing the whole window cumulatively — per-packet
                # ACKs would flood the contributors' egress ports and
                # throttle the very phase the offload accelerates
                out.extend(self._ack(p, dst))

        if len(slot.contribs) == slot.nsrc:    # fold, then release in order
            stack = np.stack([slot.contribs[i] for i in range(slot.nsrc)])
            slot.done_payload = np.asarray(self.reduce_fn(stack), np.uint8)
            self.bytes_reduced += int(stack.nbytes)
            slot.contribs = {}                 # keep only the fold result
            out.extend(self._flush(tag))
        return out

    def _flush(self, tag: int) -> List[Tuple[int, pk.Packet]]:
        """Release every completed fragment at the head of the tag's
        forwarding cursor (the carrier stream stays in PSN order)."""
        out: List[Tuple[int, pk.Packet]] = []
        nxt = self._next_fwd.get(tag, 0)
        while True:
            slot = self._slots.get((tag, nxt))
            if slot is None or slot.done_payload is None \
                    or slot.carrier is None:
                break
            self.reduced_forwarded += 1
            out.append((slot.dst, self._filled(slot.carrier,
                                               slot.done_payload)))
            slot.carrier = None
            nxt += 1
        self._next_fwd[tag] = nxt
        return out

    def _filled(self, carrier: pk.Packet, payload: np.ndarray) -> pk.Packet:
        p = carrier.clone()
        p.payload = payload.copy()
        return p

    def _src_qpn(self, p: pk.Packet, dst: int) -> int:
        try:
            return self._ack_qpn[(p.src_ip, dst)]
        except KeyError:
            raise RuntimeError(
                f"SwitchReducer: CHUNK from node {p.src_ip} to port {dst} "
                f"but no QP registered — install the collective group's "
                f"control plane before sending tagged traffic") from None

    def _ack(self, p: pk.Packet, dst: int) -> List[Tuple[int, pk.Packet]]:
        self.acks_synthesized += 1
        return [(p.src_ip, pk.make_ack(self._src_qpn(p, dst), p.psn))]

    def _nak(self, p: pk.Packet, dst: int, expected_frag: int
             ) -> List[Tuple[int, pk.Packet]]:
        # fragments map 1:1 onto consecutive PSNs within one tagged
        # stream, so the PSN of the first missing fragment is recoverable
        # from any later packet; NAK semantics resume resending there
        ack_psn = (p.psn - (p.coll_frag - expected_frag) - 1) & pk.PSN_MASK
        return [(p.src_ip,
                 pk.make_ack(self._src_qpn(p, dst), ack_psn, nak=True))]

def _per_port(value: Union[int, Sequence[int]], n_ports: int) -> List[int]:
    """Broadcast a scalar config to all ports, or validate a sequence."""
    if isinstance(value, (list, tuple)):
        if len(value) != n_ports:
            raise ValueError(f"per-port config of length {len(value)} "
                             f"for {n_ports} ports")
        return [int(v) for v in value]
    return [int(value)] * n_ports


@dataclasses.dataclass
class FabricConfig:
    """Single-switch star fabric.  ``port_bandwidth`` and ``port_delay``
    accept either a scalar (all ports alike) or a per-port sequence.

    ECN marking (RED-style, the DCQCN congestion-point role): a packet
    leaving an egress queue whose remaining depth exceeds ``ecn_kmin``
    is CE-marked with probability ramping linearly up to ``ecn_pmax``
    at ``ecn_kmax``; at or above ``ecn_kmax`` every departure is
    marked.  Marking happens at *dequeue*, so the mark reaches the
    receiver after only the wire delay — not after the packet's own
    queue sojourn.  ``ecn_kmax = 0`` (default) disables marking
    entirely — the fabric then only tail-drops, exactly the pre-ECN
    behaviour."""
    port_bandwidth: Union[int, Sequence[int]] = 4   # egress pkts per tick
    port_delay: Union[int, Sequence[int]] = 2       # ingress wire latency
    queue_capacity: int = 64                        # egress drop-tail depth
    loss_prob: float = 0.0                          # random wire loss
    ecn_kmin: int = 0                               # CE-mark ramp start
    ecn_kmax: int = 0                               # CE-mark saturation (0=off)
    ecn_pmax: float = 1.0                           # mark prob at kmax
    seed: int = 0
    # chaos mode: when set, wire-loss and RED draws come from the
    # counter-keyed hash in ``repro.core.chaos`` (loss ranked by send
    # order within the tick, RED by pop order across ports) — the same
    # stream ``core.fused`` replays in-graph.
    chaos_seed: Optional[int] = None


@dataclasses.dataclass
class PortStats:
    enqueued: int = 0
    delivered: int = 0
    tail_dropped: int = 0        # drop-tail at the egress queue
    wire_dropped: int = 0        # random loss on the ingress wire
    ecn_marked: int = 0          # CE marks applied at this egress queue
    max_depth: int = 0           # high-water mark of the egress queue

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return dataclasses.asdict(self)


def sum_port_stats(stats) -> dict:
    """Aggregate any iterable of ``PortStats`` field-wise (``max_depth``
    takes the max) — the one helper behind every fabric-level total."""
    out = {f.name: 0 for f in dataclasses.fields(PortStats)}
    for s in stats:
        for k in out:
            v = getattr(s, k)
            out[k] = max(out[k], v) if k == "max_depth" else out[k] + v
    return out


def _red_mark(rng: np.random.Generator, depth: int,
              kmin: int, kmax: int, pmax: float) -> bool:
    """RED-style CE-marking decision for a dequeue leaving ``depth``
    packets behind it (including itself).  Only draws randomness inside
    the [kmin, kmax) ramp, so configurations without ECN replay the
    exact same rng stream as before.  Shared by every egress stage of
    both switched topologies."""
    if kmax <= 0:
        return False
    if depth >= kmax:
        return True
    if depth <= kmin:
        return False
    prob = pmax * (depth - kmin) / max(kmax - kmin, 1)
    return bool(rng.random() < prob)


class _EgressQueue:
    """One drop-tail egress queue drained at a fixed bandwidth — the
    per-port machinery of ``SwitchedFabric``, factored out so the Clos
    fabric's leaf uplinks / spine downlinks / node ports are all the
    same stage.  Items are ``(packet, meta)`` pairs (``meta`` carries
    the final destination through multi-hop stages)."""

    def __init__(self, capacity: int, bandwidth: int, stats: PortStats):
        self.capacity = capacity
        self.bandwidth = bandwidth
        self.stats = stats
        self._q: Deque[Tuple[pk.Packet, object]] = collections.deque()
        # flight-recorder hook: (kind, packet, depth-after).  Installed
        # by the owning fabric's ``attach_recorder``; one ``is None``
        # test per queue operation when no recorder is attached.
        self.on_event = None

    def __len__(self) -> int:
        return len(self._q)

    def enqueue(self, p: pk.Packet, meta=None) -> bool:
        """Drop-tail admission."""
        if len(self._q) >= self.capacity:
            self.stats.tail_dropped += 1
            if self.on_event is not None:
                self.on_event("tail_drop", p, len(self._q))
            return False
        self._q.append((p, meta))
        self.stats.enqueued += 1
        self.stats.max_depth = max(self.stats.max_depth, len(self._q))
        if self.on_event is not None:
            self.on_event("enqueue", p, len(self._q))
        return True

    def drain(self, mark) -> List[Tuple[pk.Packet, object]]:
        """Pop up to ``bandwidth`` items; ``mark(depth)`` decides the CE
        bit per departure (marking at DEQUEUE: the mark reflects the
        depth the packet leaves behind and reaches the receiver after
        only the remaining wire delay — the tight feedback loop DCQCN's
        stability relies on)."""
        batch: List[Tuple[pk.Packet, object]] = []
        for _ in range(min(self.bandwidth, len(self._q))):
            if mark(len(self._q)):
                self._q[0][0].ecn = True
                self.stats.ecn_marked += 1
                if self.on_event is not None:
                    self.on_event("ecn", self._q[0][0], len(self._q))
            batch.append(self._q.popleft())
            if self.on_event is not None:
                self.on_event("dequeue", batch[-1][0], len(self._q))
        self.stats.delivered += len(batch)
        return batch

    def flush(self) -> int:
        """Discard everything queued (link/spine failure); returns the
        number of packets lost."""
        n = len(self._q)
        if self.on_event is not None:
            for i, (p, _meta) in enumerate(self._q):
                self.on_event("flush", p, n - 1 - i)
        self._q.clear()
        return n


def _queue_hook(fabric, rec, track):
    """Build an ``_EgressQueue.on_event`` closure recording lifecycle
    events on ``track`` at the owning fabric's current tick; enqueue /
    dequeue additionally emit a ``qdepth`` sample so Perfetto renders a
    queue-depth counter graph per port/uplink/downlink."""
    def on_event(kind, p, depth):
        rec.record(fabric.now, kind, track, qpn=p.qpn, psn=p.psn)
        if kind in ("enqueue", "dequeue"):
            rec.record(fabric.now, "qdepth", track, depth=depth)
    return on_event


class SwitchedFabric:
    """A single switch; node ``i`` hangs off port ``i``.

    Datapath per packet: ingress wire (``port_delay[src]`` ticks, seeded
    random loss) -> destination port's egress FIFO (drop-tail at
    ``queue_capacity``) -> drained at ``port_bandwidth[dst]`` packets
    per tick.  The egress queue is *shared by all flows* targeting that
    port — congestion (incast) shows up as drop-tail losses the RDMA
    layer must recover via retransmission, exactly like a
    shallow-buffered data-center switch.
    """

    def __init__(self, n_nodes: int, cfg: Optional[FabricConfig] = None):
        cfg = cfg if cfg is not None else FabricConfig()
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.bandwidth = _per_port(cfg.port_bandwidth, n_nodes)
        self.delay = _per_port(cfg.port_delay, n_nodes)
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0
        self._seq = 0
        # packets on the ingress wire: (arrival_tick, seq, dst, packet)
        self._wire: List[Tuple[int, int, int, pk.Packet]] = []
        self.port_stats = [PortStats() for _ in range(n_nodes)]
        self.egress: List[_EgressQueue] = [
            _EgressQueue(cfg.queue_capacity, self.bandwidth[i],
                         self.port_stats[i]) for i in range(n_nodes)]
        self.reducer: Optional[SwitchReducer] = None
        self.recorder = None
        self.injected = 0        # send() calls (conservation anchor)
        self._ctick = -1         # chaos mode: per-tick send / pop ranks
        self._csend = 0
        self._cpop = 0

    def _chaos_rank(self, kind: str) -> int:
        """Next chaos rank within the current tick (``kind`` selects the
        send or pop counter; both reset together on a new tick)."""
        if self.now != self._ctick:
            self._ctick, self._csend, self._cpop = self.now, 0, 0
        if kind == "send":
            i, self._csend = self._csend, self._csend + 1
        else:
            i, self._cpop = self._cpop, self._cpop + 1
        return i

    def attach_reducer(self, reducer: SwitchReducer):
        """Install the in-fabric reduction offload (collective control
        plane).  CHUNK-tagged packets are then diverted to the reducer
        as they leave the ingress wire, before the egress queues.  One
        reducer per fabric: silently replacing an attached one would
        strand the first group's tagged traffic on the wrong control
        plane (wrong ACK QPs, wrong fold dtype)."""
        if self.reducer is not None and self.reducer is not reducer:
            raise RuntimeError(
                "SwitchedFabric already has a reducer attached; offload "
                "groups sharing a fabric must share one AllreduceService")
        self.reducer = reducer

    def send(self, src: int, dst: int, p: pk.Packet):
        self.injected += 1
        st = self.port_stats[dst]
        if self.cfg.loss_prob:
            if self.cfg.chaos_seed is not None:
                lost = _chaos.hash32(
                    self.cfg.chaos_seed, _chaos.TAG_LOSS, self.now,
                    self._chaos_rank("send")) < _chaos.u32_prob(
                        self.cfg.loss_prob)
            else:
                lost = self.rng.random() < self.cfg.loss_prob
            if lost:
                st.wire_dropped += 1
                if self.recorder is not None:
                    self.recorder.record(self.now, "wire_drop",
                                         ("node", src),
                                         qpn=p.qpn, psn=p.psn, dst=dst)
                return
        if self.recorder is not None:
            self.recorder.record(self.now, "inject", ("node", src),
                                 qpn=p.qpn, psn=p.psn, dst=dst)
        self._seq += 1
        heapq.heappush(self._wire,
                       (self.now + self.delay[src], self._seq, dst, p))

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        """Advance one tick: move arrived packets into egress queues
        (drop-tail), then drain each port at its bandwidth.  Returns
        ``{(-1, dst): packets}`` — the switch is the source."""
        self.now += 1
        while self._wire and self._wire[0][0] <= self.now:
            _, _, dst, p = heapq.heappop(self._wire)
            if p.coll_tag and self.reducer is not None:
                # in-fabric reduction: the contribution is consumed at
                # the hop; only summed forwards / synthesized ACKs enter
                # the (drop-tail) egress queues
                for port, outp in self.reducer.on_packet(dst, p):
                    self._enqueue(port, outp)
                continue
            self._enqueue(dst, p)
        out: Dict[Tuple[int, int], List[pk.Packet]] = {}
        for dst in range(self.n_nodes):
            if not len(self.egress[dst]):
                continue
            batch = [p for p, _ in self.egress[dst].drain(self._ecn_mark)]
            out[(-1, dst)] = batch
        return out

    def _enqueue(self, dst: int, p: pk.Packet):
        """Drop-tail admission into a port's egress queue."""
        self.egress[dst].enqueue(p)

    def _ecn_mark(self, depth: int) -> bool:
        if self.cfg.chaos_seed is not None and self.cfg.ecn_kmax > 0:
            # every pop consumes one rank (whether or not the depth is
            # inside the ramp), so the fused core can rank pops by
            # (port asc, pop order) without replaying the ramp test
            return _chaos.red_mark(self.cfg.chaos_seed, self.now,
                                   self._chaos_rank("pop"), depth,
                                   self.cfg.ecn_kmin, self.cfg.ecn_kmax,
                                   self.cfg.ecn_pmax)
        return _red_mark(self.rng, depth, self.cfg.ecn_kmin,
                         self.cfg.ecn_kmax, self.cfg.ecn_pmax)

    def quiescent(self) -> bool:
        return (not self._wire and all(not len(q) for q in self.egress)
                and (self.reducer is None or self.reducer.in_flight == 0))

    # ---- telemetry ----------------------------------------------------
    def attach_recorder(self, rec):
        """Record packet lifecycle events (inject, per-port enqueue /
        dequeue with queue depth, ECN mark, drops) into a
        ``telemetry.FlightRecorder``; one track per port."""
        self.recorder = rec
        for i, q in enumerate(self.egress):
            q.on_event = _queue_hook(self, rec, ("port", i))

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``):
        conservation holds as ``injected == wire_dropped + tail_dropped
        + delivered + in_flight`` (absent a reducer, which consumes
        contributions and synthesizes new packets at the hop)."""
        snap = {"now": self.now, "injected": self.injected,
                "in_flight": (len(self._wire)
                              + sum(len(q) for q in self.egress)),
                **sum_port_stats(self.port_stats),
                "ports": {i: s.snapshot()
                          for i, s in enumerate(self.port_stats)}}
        if self.reducer is not None:
            snap["reducer"] = self.reducer.snapshot()
        return snap

    @property
    def total_tail_dropped(self) -> int:
        return sum_port_stats(self.port_stats)["tail_dropped"]

    @property
    def total_delivered(self) -> int:
        return sum_port_stats(self.port_stats)["delivered"]

    @property
    def total_ecn_marked(self) -> int:
        return sum_port_stats(self.port_stats)["ecn_marked"]


def dcqcn_fabric_profile() -> FabricConfig:
    """The calibrated ECN-marking fabric for DCQCN experiments (swept in
    benchmarks/fig6_multiqp.py): mark lightly from Kmin=8, saturate at
    Kmax=24, keep half the drop-tail headroom above Kmax to absorb AI
    overshoot between CNPs.  The single source of truth — the incast
    default, the CC bench and the acceptance tests all measure this
    exact profile."""
    return FabricConfig(port_bandwidth=4, port_delay=2, queue_capacity=48,
                        ecn_kmin=8, ecn_kmax=24, ecn_pmax=0.05, seed=7)


# ---------------------------------------------------------------------------
# Leaf-spine (Clos) multipath fabric
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClosConfig:
    """Two-tier leaf-spine fabric.  ``port_bandwidth`` / ``port_delay``
    accept a scalar or a per-node sequence; ``spine_delay`` a scalar or
    per-spine sequence (asymmetric spine delays are what make per-packet
    spraying genuinely reorder).  ECN marking (same RED ramp as
    ``FabricConfig``) applies at every egress stage — node ports, leaf
    uplinks and spine downlinks — so a congested spine plane CE-marks
    the packets that crossed it, and the receiver's CNPs can carry the
    path back to a per-path DCQCN reaction point.

    ``path_mode`` is the *fabric-side* route choice for packets the
    sender did not stamp (``Packet.path_id < 0``) or whose stamped
    spine has failed: ``"ecmp"`` hashes (src, dst, qpn) so one flow
    stays on one spine; ``"spray"`` round-robins per source across the
    live spines.  Sender-stamped live paths are always honored."""
    nodes_per_leaf: int = 1
    n_spines: int = 2
    port_bandwidth: Union[int, Sequence[int]] = 4   # node egress pkts/tick
    port_delay: Union[int, Sequence[int]] = 2       # node ingress wire
    queue_capacity: int = 64                        # node-port egress depth
    uplink_bandwidth: int = 4                       # leaf->spine drain rate
    uplink_capacity: int = 64
    downlink_bandwidth: int = 4                     # spine->leaf drain rate
    downlink_capacity: int = 64
    spine_delay: Union[int, Sequence[int]] = 2      # per-spine wire latency
    loss_prob: float = 0.0                          # random ingress-wire loss
    ecn_kmin: int = 0
    ecn_kmax: int = 0                               # 0 = marking off
    ecn_pmax: float = 1.0
    path_mode: str = "ecmp"                         # | "spray"
    seed: int = 0


class ClosFabric:
    """A two-tier Clos: node ``i`` hangs off leaf ``i // nodes_per_leaf``;
    every leaf connects to every spine.  Same surface as
    ``SwitchedFabric`` (``send`` / ``tick`` / ``quiescent`` / ``now``).

    Datapath per cross-leaf packet:
        ingress wire (``port_delay[src]``, seeded random loss)
        -> leaf uplink queue toward the chosen spine (drop-tail + RED)
        -> spine wire (``spine_delay[s]``)
        -> spine downlink queue toward the destination leaf
        -> spine wire (``spine_delay[s]``) back down
        -> destination node's port queue -> drained at port bandwidth.
    Same-leaf packets skip the spine stages entirely (one wire + the
    port queue — exactly the single-switch datapath).

    Spraying across spines with asymmetric ``spine_delay`` makes
    packets of one flow overtake each other — the reorder regime
    go-back-N collapses under and selective-repeat RX absorbs.
    ``fail_spine`` kills a plane mid-run: everything queued on or
    flying toward it is lost (counted in ``failure_dropped``) and
    future picks re-route to the surviving spines.
    """

    # wire-event stage codes (heap entries stay tuple-comparable)
    _UP, _DOWN, _PORT = 0, 1, 2

    def __init__(self, n_nodes: int, cfg: Optional[ClosConfig] = None):
        cfg = cfg if cfg is not None else ClosConfig()
        if cfg.path_mode not in ("ecmp", "spray"):
            raise ValueError(f"unknown path_mode {cfg.path_mode!r}; "
                             f"choose from ('ecmp', 'spray')")
        if cfg.n_spines < 1:
            raise ValueError("ClosFabric needs at least one spine")
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.nodes_per_leaf = max(1, cfg.nodes_per_leaf)
        self.n_leaves = -(-n_nodes // self.nodes_per_leaf)
        self.n_spines = cfg.n_spines
        self.bandwidth = _per_port(cfg.port_bandwidth, n_nodes)
        self.delay = _per_port(cfg.port_delay, n_nodes)
        self.spine_delay = _per_port(cfg.spine_delay, cfg.n_spines)
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0
        self._seq = 0
        # wire events: (arrival, seq, stage, leaf, spine, dst, packet)
        self._wire: List[Tuple[int, int, int, int, int, int, pk.Packet]] = []
        self.port_stats = [PortStats() for _ in range(n_nodes)]
        self.down = [_EgressQueue(cfg.queue_capacity, self.bandwidth[i],
                                  self.port_stats[i])
                     for i in range(n_nodes)]
        self.uplink_stats = [[PortStats() for _ in range(self.n_spines)]
                             for _ in range(self.n_leaves)]
        self.up = [[_EgressQueue(cfg.uplink_capacity, cfg.uplink_bandwidth,
                                 self.uplink_stats[lf][s])
                    for s in range(self.n_spines)]
                   for lf in range(self.n_leaves)]
        self.spine_stats = [[PortStats() for _ in range(self.n_leaves)]
                            for _ in range(self.n_spines)]
        self.spdown = [[_EgressQueue(cfg.downlink_capacity,
                                     cfg.downlink_bandwidth,
                                     self.spine_stats[s][lf])
                        for lf in range(self.n_leaves)]
                       for s in range(self.n_spines)]
        self._alive: List[int] = list(range(self.n_spines))
        self.failed_spines: List[int] = []
        self._rr: Dict[int, int] = {}       # per-src spray cursor
        # telemetry
        self.spine_pkts = [0] * self.n_spines   # packets forwarded via spine
        self.failure_dropped = 0                # lost to fail_spine()
        self.rerouted = 0                       # stamped path dead, re-picked
        self.injected = 0                       # send() calls
        self.recorder = None

    # ---- topology helpers ---------------------------------------------
    def leaf_of(self, node: int) -> int:
        return node // self.nodes_per_leaf

    @property
    def n_paths(self) -> int:
        """Parallel spine planes — what a spraying sender spreads over."""
        return self.n_spines

    @property
    def alive_paths(self) -> Tuple[int, ...]:
        return tuple(self._alive)

    # ---- datapath ------------------------------------------------------
    def send(self, src: int, dst: int, p: pk.Packet):
        self.injected += 1
        st = self.port_stats[dst]
        if self.cfg.loss_prob and self.rng.random() < self.cfg.loss_prob:
            st.wire_dropped += 1
            if self.recorder is not None:
                self.recorder.record(self.now, "wire_drop", ("node", src),
                                     qpn=p.qpn, psn=p.psn, dst=dst)
            return
        if self.recorder is not None:
            self.recorder.record(self.now, "inject", ("node", src),
                                 qpn=p.qpn, psn=p.psn, dst=dst)
        self._seq += 1
        if self.leaf_of(src) == self.leaf_of(dst):
            p.path_id = -1                  # no spine crossed
            heapq.heappush(self._wire, (self.now + self.delay[src],
                                        self._seq, self._PORT, 0, 0, dst, p))
            return
        s = self._route(src, dst, p)
        p.path_id = s                       # record the path actually taken
        heapq.heappush(self._wire, (self.now + self.delay[src], self._seq,
                                    self._UP, self.leaf_of(src), s, dst, p))

    def _route(self, src: int, dst: int, p: pk.Packet) -> int:
        alive = self._alive
        if not alive:
            raise RuntimeError("ClosFabric: every spine has failed")
        pid = p.path_id
        if 0 <= pid < self.n_spines:
            if pid in alive:
                return pid                  # honor the sender's stamp
            self.rerouted += 1              # stamped plane is dead: re-pick
            if self.recorder is not None:
                self.recorder.record(self.now, "reroute", ("spine", pid),
                                     qpn=p.qpn, psn=p.psn)
        if self.cfg.path_mode == "spray":
            c = self._rr.get(src, 0)
            self._rr[src] = c + 1
            return alive[c % len(alive)]
        # ECMP: stable flow hash over the live spines
        h = (src * 0x9E3779B1 + dst * 0x85EBCA77
             + p.qpn * 0xC2B2AE3D) & 0xFFFFFFFF
        return alive[h % len(alive)]

    def tick(self) -> Dict[Tuple[int, int], List[pk.Packet]]:
        """Advance one tick: land wire arrivals in their stage queues,
        then drain every queue in deterministic (index) order.  Returns
        ``{(-1, dst): packets}`` exactly like ``SwitchedFabric``."""
        self.now += 1
        while self._wire and self._wire[0][0] <= self.now:
            _, _, stage, lf, s, dst, p = heapq.heappop(self._wire)
            if stage == self._UP:
                self.up[lf][s].enqueue(p, dst)
            elif stage == self._DOWN:
                self.spdown[s][lf].enqueue(p, dst)
            else:
                self.down[dst].enqueue(p)
        # leaf uplinks -> spine wires
        for lf in range(self.n_leaves):
            for s in range(self.n_spines):
                for p, dst in self.up[lf][s].drain(self._ecn_mark):
                    self.spine_pkts[s] += 1
                    self._seq += 1
                    heapq.heappush(
                        self._wire,
                        (self.now + self.spine_delay[s], self._seq,
                         self._DOWN, self.leaf_of(dst), s, dst, p))
        # spine downlinks -> destination-leaf wires
        for s in range(self.n_spines):
            for lf in range(self.n_leaves):
                for p, dst in self.spdown[s][lf].drain(self._ecn_mark):
                    self._seq += 1
                    heapq.heappush(
                        self._wire,
                        (self.now + self.spine_delay[s], self._seq,
                         self._PORT, 0, 0, dst, p))
        # node ports -> deliver
        out: Dict[Tuple[int, int], List[pk.Packet]] = {}
        for dst in range(self.n_nodes):
            if not len(self.down[dst]):
                continue
            out[(-1, dst)] = [p for p, _ in self.down[dst].drain(
                self._ecn_mark)]
        return out

    def _ecn_mark(self, depth: int) -> bool:
        return _red_mark(self.rng, depth, self.cfg.ecn_kmin,
                         self.cfg.ecn_kmax, self.cfg.ecn_pmax)

    # ---- failure injection --------------------------------------------
    def fail_spine(self, s: int) -> int:
        """Kill spine plane ``s``: every packet queued on it or flying
        toward/from it is lost; future picks route around it.  Returns
        the number of packets dropped (also accumulated in
        ``failure_dropped``) — the transport recovers them by
        retransmission like any other loss."""
        if s not in self._alive:
            return 0
        self._alive.remove(s)
        self.failed_spines.append(s)
        dropped = 0
        for lf in range(self.n_leaves):
            dropped += self.up[lf][s].flush()
            dropped += self.spdown[s][lf].flush()
        keep = [ev for ev in self._wire
                if not (ev[2] in (self._UP, self._DOWN) and ev[4] == s)]
        dropped += len(self._wire) - len(keep)
        heapq.heapify(keep)
        self._wire = keep
        self.failure_dropped += dropped
        if self.recorder is not None:
            self.recorder.record(self.now, "spine_fail", ("spine", s),
                                 dropped=dropped)
        return dropped

    def quiescent(self) -> bool:
        return (not self._wire
                and all(not len(q) for q in self.down)
                and all(not len(q) for row in self.up for q in row)
                and all(not len(q) for row in self.spdown for q in row))

    # ---- telemetry -----------------------------------------------------
    def attach_recorder(self, rec):
        """Record packet lifecycle events across every stage — node
        ports, leaf uplinks, spine downlinks — into a
        ``telemetry.FlightRecorder``: one track per port, per
        leaf->spine uplink and per spine->leaf downlink, so an incast
        or a spine failure is visually debuggable in Perfetto."""
        self.recorder = rec
        for i, q in enumerate(self.down):
            q.on_event = _queue_hook(self, rec, ("port", i))
        for lf in range(self.n_leaves):
            for s in range(self.n_spines):
                self.up[lf][s].on_event = _queue_hook(
                    self, rec, ("uplink", f"leaf{lf}->spine{s}"))
        for s in range(self.n_spines):
            for lf in range(self.n_leaves):
                self.spdown[s][lf].on_event = _queue_hook(
                    self, rec, ("spdown", f"spine{s}->leaf{lf}"))

    def snapshot(self) -> dict:
        """Common telemetry shape.  Conservation: ``injected ==
        ports/wire_dropped + tail_dropped(all stages) + failure_dropped
        + ports/delivered + in_flight``."""
        up_flat = [s for row in self.uplink_stats for s in row]
        sp_flat = [s for row in self.spine_stats for s in row]
        return {"now": self.now, "injected": self.injected,
                "failure_dropped": self.failure_dropped,
                "rerouted": self.rerouted,
                "alive_spines": len(self._alive),
                "spine_pkts": list(self.spine_pkts),
                "in_flight": (len(self._wire)
                              + sum(len(q) for q in self.down)
                              + sum(len(q) for row in self.up for q in row)
                              + sum(len(q) for row in self.spdown
                                    for q in row)),
                "ports": {**sum_port_stats(self.port_stats),
                          **{i: s.snapshot()
                             for i, s in enumerate(self.port_stats)}},
                "uplinks": sum_port_stats(up_flat),
                "spine_down": sum_port_stats(sp_flat)}

    @property
    def total_tail_dropped(self) -> int:
        return (sum_port_stats(self.port_stats)["tail_dropped"]
                + sum_port_stats(s for row in self.uplink_stats
                                 for s in row)["tail_dropped"]
                + sum_port_stats(s for row in self.spine_stats
                                 for s in row)["tail_dropped"])

    @property
    def total_delivered(self) -> int:
        return sum_port_stats(self.port_stats)["delivered"]

    @property
    def total_ecn_marked(self) -> int:
        return (sum_port_stats(self.port_stats)["ecn_marked"]
                + sum_port_stats(s for row in self.uplink_stats
                                 for s in row)["ecn_marked"]
                + sum_port_stats(s for row in self.spine_stats
                                 for s in row)["ecn_marked"])


@dataclasses.dataclass
class IncastResult:
    receiver: object                  # RdmaNode (port 0, the hot port)
    senders: List[object]             # RdmaNode per sender
    fabric: SwitchedFabric
    ticks: int                        # simulated ticks until quiescent
    payloads: List[np.ndarray]        # what sender i wrote (QPN i+1 at rx)


def incast_scenario(n_senders: int, *, message_bytes: int = 65536,
                    fabric_cfg: Optional[FabricConfig] = None,
                    rx_credits: int = 64, fc_window: int = 16,
                    max_ticks: int = 300_000,
                    engine: str = "batched",
                    congestion_control: str = "ack_clocked",
                    recorder=None,
                    epoch_mode: Optional[str] = None) -> IncastResult:
    """The canonical congestion scenario: ``n_senders`` nodes RDMA-WRITE
    simultaneously into one receiver through a shallow-buffered switch
    port.  Runs until the fabric drains — callers assert delivery and
    inspect drop/retransmit stats.

    ``congestion_control="dcqcn"`` arms the full ECN loop: the default
    fabric config then CE-marks above Kmin (unless an explicit
    ``fabric_cfg`` overrides it) and every sender runs the DCQCN
    reaction point, so drop-tail losses give way to rate convergence.
    """
    from repro.core.flow_control import DcqcnConfig     # cycle-free import
    from repro.core.rdma import RdmaNode, run_network

    if fabric_cfg is not None:
        cfg = fabric_cfg
    elif congestion_control == "dcqcn":
        cfg = dcqcn_fabric_profile()
    else:
        cfg = FabricConfig(port_bandwidth=4, port_delay=2,
                           queue_capacity=32, seed=7)
    fabric = SwitchedFabric(n_senders + 1, cfg)
    # the reaction point's line rate is the hot port's drain rate; flows
    # start at a quarter of it — the fabric models no PFC, so a blind
    # first-RTT burst at line rate would only be drop-tail carnage
    line = float(_per_port(cfg.port_bandwidth, n_senders + 1)[0])
    dcqcn = DcqcnConfig(line_rate=line, initial_rate=line / 4)
    recv = RdmaNode(0, fabric, rx_credits=rx_credits, engine=engine)
    senders = [RdmaNode(i + 1, fabric, fc_window=fc_window, engine=engine,
                        congestion_control=congestion_control, dcqcn=dcqcn)
               for i in range(n_senders)]
    if recorder is not None:
        fabric.attach_recorder(recorder)
        for n in [recv] + senders:
            n.attach_recorder(recorder)
    rng = np.random.default_rng(13)
    work = []
    for s in senders:
        qpn, _, _ = s.init_rdma(message_bytes, recv)
        data = rng.integers(0, 256, message_bytes, dtype=np.uint8)
        work.append((s, qpn, data))
    for s, qpn, data in work:
        s.rdma_write(qpn, data)
    ticks = run_network([recv] + senders, max_ticks=max_ticks,
                        epoch_mode=epoch_mode)
    return IncastResult(receiver=recv, senders=senders, fabric=fabric,
                        ticks=ticks, payloads=[d for _, _, d in work])


def clos_incast_scenario(n_senders: int, *, message_bytes: int = 65536,
                         clos_cfg: Optional[ClosConfig] = None,
                         rx_mode: str = "selective_repeat",
                         path_select: Optional[str] = "spray",
                         rx_credits: int = 64, fc_window: int = 16,
                         max_ticks: int = 300_000,
                         engine: str = "batched",
                         congestion_control: str = "ack_clocked",
                         fail_spine_at: Optional[int] = None,
                         fail_spine: int = 0,
                         recorder=None) -> IncastResult:
    """The multipath congestion scenario: ``n_senders`` nodes (one per
    leaf) RDMA-WRITE simultaneously into node 0 across a leaf-spine
    fabric with asymmetric spine delays.  With ``path_select="spray"``
    every flow's packets arrive genuinely out of order — the regime the
    ``rx_mode`` argument exists to compare (``"go_back_n"`` NAKs and
    re-sends whole windows; ``"selective_repeat"`` absorbs the reorder
    and re-sends only real gaps).  ``fail_spine_at`` kills spine
    ``fail_spine`` at that tick mid-transfer; the transport must
    recover over the survivors."""
    from repro.core.flow_control import DcqcnConfig     # cycle-free import
    from repro.core.rdma import RdmaNode, network_pending, step_network

    cfg = clos_cfg if clos_cfg is not None else ClosConfig(
        nodes_per_leaf=1, n_spines=2, port_bandwidth=4, port_delay=1,
        queue_capacity=48, spine_delay=(1, 5), seed=7,
        path_mode=path_select or "ecmp")
    fabric = ClosFabric(n_senders + 1, cfg)
    line = float(_per_port(cfg.port_bandwidth, n_senders + 1)[0])
    dcqcn = DcqcnConfig(line_rate=line, initial_rate=line / 4)
    kw = dict(rx_mode=rx_mode, path_select=path_select, engine=engine)
    recv = RdmaNode(0, fabric, rx_credits=rx_credits,
                    fc_window=fc_window, **kw)
    senders = [RdmaNode(i + 1, fabric, fc_window=fc_window,
                        congestion_control=congestion_control,
                        dcqcn=dcqcn, **kw)
               for i in range(n_senders)]
    if recorder is not None:
        fabric.attach_recorder(recorder)
        for n in [recv] + senders:
            n.attach_recorder(recorder)
    rng = np.random.default_rng(13)
    work = []
    for s in senders:
        qpn, _, _ = s.init_rdma(message_bytes, recv)
        data = rng.integers(0, 256, message_bytes, dtype=np.uint8)
        work.append((s, qpn, data))
    for s, qpn, data in work:
        s.rdma_write(qpn, data)
    nodes = [recv] + senders
    ticks, idle = max_ticks, 0
    for t in range(max_ticks):
        if fail_spine_at is not None and t == fail_spine_at:
            fabric.fail_spine(fail_spine)
        step_network(nodes)
        if network_pending(nodes):
            idle = 0
        else:
            idle += 1
            if idle >= 8:
                ticks = t
                break
    return IncastResult(receiver=recv, senders=senders, fabric=fabric,
                        ticks=ticks, payloads=[d for _, _, d in work])
