"""RoCE BALBOA core: the paper's contribution as composable modules.

packet / qp / pipeline   — RoCE v2 framing, per-QP tables, RX/TX FSMs
flow_control             — ACK-clocked windows + RX crediting (§4.3/4.4)
retransmit / netsim      — reliability under loss (§4.2); netsim also
                           models a switched fabric (incast/congestion)
services                 — on-path & parallel-path enhancements (§5)
rdma                     — the full endpoint (verbs of §4.6)
ingest                   — storage -> RDMA -> services -> device (§8)
sniffer                  — PCAP traffic capture (§4.7)
collectives              — ring/tree collectives over the verbs, with
                           the in-fabric reduction offload (the switch
                           folds CHUNK payloads at the hop; the ML-
                           fabric workload of the paper's §1 pitch)
telemetry                — MetricRegistry (hierarchical typed metrics,
                           every stats surface registers in) + the
                           FlightRecorder tick-stamped event ring with
                           Perfetto chrome://tracing export

FPGA -> TPU design dual (the repo-wide translation rule): the FPGA
realizes deep pipelines processing one beat per cycle with per-QP state
in BRAM; these modules keep identical semantics (same tables, same FSM
decisions, same wire format) but move the parallelism to the axes a
vector machine has — SIMD across packets and payload bytes, and
vectorization across queue pairs, which is the axis the paper scales
along (hundreds of QPs).  See docs/ARCHITECTURE.md for the full
paper-to-code map.
"""
