"""RoCE BALBOA core: the paper's contribution as composable modules.

packet / qp / pipeline   — RoCE v2 framing, per-QP tables, RX/TX FSMs
flow_control             — ACK-clocked windows + RX crediting (§4.3/4.4)
retransmit / netsim      — reliability under loss (§4.2)
services                 — on-path & parallel-path enhancements (§5)
rdma                     — the full endpoint (verbs of §4.6)
ingest                   — storage -> RDMA -> services -> device (§8)
sniffer                  — PCAP traffic capture (§4.7)
"""
