"""Unified telemetry: metric registry + flight recorder (observability).

The paper's pitch is that BALBOA is *inspectable* where commercial NICs
are black boxes.  This module is the repo-wide observability plane that
backs that claim for the reproduction:

``MetricRegistry``
    A hierarchical registry of typed metrics (counters / gauges /
    histograms) plus *providers* — existing stats surfaces
    (``PortStats``, ``NodeStats``, ``CreditManager``, ``StreamReport``,
    fabric/reducer/rate-controller telemetry) that expose a common
    ``snapshot() -> dict`` shape.  ``snapshot()`` walks everything into
    one nested dict; ``flat()`` flattens it to ``"a/b/c" -> value`` for
    JSON export (the fig benches embed it in their ``--json`` output,
    which is what ``benchmarks/regress.py`` diffs across commits);
    ``diff()`` subtracts two snapshots leaf-wise.

``FlightRecorder``
    A bounded ring of sim-tick-timestamped packet-lifecycle events
    (inject, per-hop enqueue/dequeue with queue depth, ECN mark, drop,
    SACK/NAK, retransmit, CNP, completion, spine failure, stream tile
    events, collective phases) recorded by ``netsim`` / ``rdma`` /
    ``ingest`` / ``collectives`` when a recorder is attached — and by
    nothing (one ``is None`` test per event site) when it is not.
    ``chrome_trace()`` exports Chrome-trace / Perfetto JSON where tracks
    are ports, spines, uplinks and QPs, so an 8:1 incast or a mid-run
    spine failure is visually debuggable in ``chrome://tracing``.

Determinism contract: every timestamp is the simulator's integer tick —
there is NO wall-clock anywhere in ``repro.core`` (enforced by
``tests/test_telemetry.py``), so two runs of the same seeded config
produce byte-identical trace exports.

FPGA -> TPU design dual: the FPGA taps counters out of BRAM next to
each pipeline stage and streams trace words over a dedicated DMA ring;
here the same per-stage counters ride the jitted engines' carried state
as ``(Q,)`` arrays (the ``ecn_cnt`` pattern — harvested only at epoch
boundaries, zero extra host syncs) and the host-side control planes
record into a deque.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple, Union

# ---------------------------------------------------------------------------
# Typed metrics
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float):
        self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bound histogram: counts per bucket plus count/sum/min/max.
    Bounds are upper edges; values beyond the last bound land in the
    overflow bucket."""

    __slots__ = ("bounds", "buckets", "count", "total", "vmin", "vmax")

    DEFAULT_BOUNDS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BOUNDS):
        self.bounds = tuple(bounds)
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float):
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    def snapshot(self) -> Dict[str, Union[int, float, list]]:
        return {"count": self.count, "sum": self.total,
                "min": 0 if self.vmin is None else self.vmin,
                "max": 0 if self.vmax is None else self.vmax,
                "buckets": list(self.buckets)}


Provider = Union[Counter, Gauge, Histogram, Callable[[], dict], object]


class MetricRegistry:
    """Hierarchical metric registry.  Paths are ``/``-separated; a
    registered *provider* is either an owned metric (``counter()`` /
    ``gauge()`` / ``histogram()``), any object with a ``snapshot()``
    method, or a zero-arg callable returning a dict — which is how
    every pre-existing ad-hoc stats surface plugs in without being
    rewritten."""

    def __init__(self):
        self._providers: Dict[str, Provider] = {}

    # ---- registration -------------------------------------------------
    def register(self, path: str, provider: Provider) -> Provider:
        if not path or path.startswith("/") or path.endswith("/"):
            raise ValueError(f"bad metric path {path!r}")
        if path in self._providers:
            raise ValueError(f"metric path {path!r} already registered")
        self._providers[path] = provider
        return provider

    def deregister(self, path: str):
        self._providers.pop(path, None)

    def counter(self, path: str) -> Counter:
        return self.register(path, Counter())

    def gauge(self, path: str, value: float = 0.0) -> Gauge:
        return self.register(path, Gauge(value))

    def histogram(self, path: str,
                  bounds: Tuple[float, ...] = Histogram.DEFAULT_BOUNDS
                  ) -> Histogram:
        return self.register(path, Histogram(bounds))

    def paths(self) -> List[str]:
        return sorted(self._providers)

    # ---- export --------------------------------------------------------
    @staticmethod
    def _resolve(provider: Provider):
        if callable(provider) and not hasattr(provider, "snapshot"):
            return provider()
        return provider.snapshot()

    def snapshot(self) -> dict:
        """Nested dict keyed by path components; provider dicts embed
        as-is (and may nest further)."""
        out: dict = {}
        for path in sorted(self._providers):
            node = out
            parts = path.split("/")
            for part in parts[:-1]:
                node = node.setdefault(part, {})
                if not isinstance(node, dict):
                    raise ValueError(f"metric path {path!r} collides with "
                                     f"a leaf at {part!r}")
            node[parts[-1]] = self._resolve(self._providers[path])
        return out

    def flat(self, snap: Optional[dict] = None) -> Dict[str, Union[int, float]]:
        """Flatten a (possibly nested) snapshot into ``"a/b/c" -> value``
        with scalar leaves only (lists index as ``path/i``)."""
        return flatten(self.snapshot() if snap is None else snap)

    def diff(self, before: dict, after: dict) -> Dict[str, Union[int, float]]:
        """Leaf-wise ``after - before`` over the numeric leaves both
        snapshots share — what changed during an epoch."""
        fb, fa = flatten(before), flatten(after)
        return {k: fa[k] - fb[k] for k in fa
                if k in fb and isinstance(fa[k], (int, float))
                and isinstance(fb[k], (int, float))
                and not isinstance(fa[k], bool)}


def flatten(tree: dict, prefix: str = "") -> Dict[str, Union[int, float]]:
    out: Dict[str, Union[int, float]] = {}
    for k in sorted(tree, key=str):
        v = tree[k]
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "/"))
        elif isinstance(v, (list, tuple)):
            out.update(flatten({i: x for i, x in enumerate(v)}, key + "/"))
        elif isinstance(v, (int, float)):
            out[key] = v
    return out


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------

# event kinds -> Chrome-trace phase.  "qdepth" renders as a counter
# track (ph "C"); events carrying a ``dur`` attr render as complete
# spans (ph "X"); everything else is an instant (ph "i").
EVENT_KINDS = (
    "inject", "wire_drop", "enqueue", "dequeue", "tail_drop", "ecn",
    "flush", "spine_fail", "reroute", "nak", "sack", "retransmit",
    "cnp_tx", "cnp_rx", "completion", "qp_error", "qdepth",
    "stream_issue", "stream_tile", "stream_done", "stream_refetch",
    "coll_transfer",
)

Track = Tuple[str, Union[int, str]]      # (category, instance)


@dataclasses.dataclass(frozen=True)
class Event:
    tick: int
    kind: str
    track: Track
    attrs: Tuple[Tuple[str, Union[int, float, str]], ...] = ()


class FlightRecorder:
    """Bounded, sim-tick-timestamped event ring.

    ``record`` is the single entry point every instrumented subsystem
    calls; the ring is a ``deque(maxlen=capacity)`` so a long run never
    grows without bound (``dropped_events`` counts overwrites).  The
    per-kind totals in ``counts`` are monotonic and independent of the
    ring, so they reconcile exactly with the ``MetricRegistry`` snapshot
    even after wraparound; the *exported trace* only reconciles while
    the ring has not wrapped (``dropped_events == 0``)."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError("FlightRecorder capacity must be >= 1")
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.counts: Dict[str, int] = {}
        self.total_events = 0
        self.dropped_events = 0

    # ---- recording -----------------------------------------------------
    def record(self, tick: int, kind: str, track: Track, **attrs):
        self.total_events += 1
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if len(self._ring) == self.capacity:
            self.dropped_events += 1
        self._ring.append(Event(int(tick), kind, track,
                                tuple(sorted(attrs.items()))))

    def events(self, kind: Optional[str] = None) -> List[Event]:
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    def clear(self):
        self._ring.clear()
        self.counts = {}
        self.total_events = 0
        self.dropped_events = 0

    # ---- registry integration ------------------------------------------
    def snapshot(self) -> dict:
        """The recorder's own registry surface: monotonic per-kind event
        totals (+ ring health)."""
        return {"events_total": self.total_events,
                "events_dropped": self.dropped_events,
                "events_retained": len(self._ring),
                "by_kind": dict(sorted(self.counts.items()))}

    # ---- Chrome-trace / Perfetto export --------------------------------
    # track category -> (pid, sort index); unknown categories get pids
    # after the known ones, in first-seen order per export (the event
    # stream is deterministic, so the mapping is too)
    _PID_ORDER = ("port", "uplink", "spdown", "spine", "link", "node",
                  "qp", "stripe", "coll")

    def chrome_trace(self, *, tick_us: int = 1) -> dict:
        """Render the retained ring as a Chrome-trace JSON object
        (``chrome://tracing`` / Perfetto's legacy JSON importer).

        Mapping: track *category* -> process, track *instance* ->
        thread, so ports/spines/uplinks/QPs each get their own named
        track.  ``qdepth`` events render as counter tracks (queue-depth
        graphs), ``dur``-carrying events as complete spans, the rest as
        instants.  Timestamps are ``tick * tick_us`` microseconds."""
        cats: Dict[str, int] = {}
        tids: Dict[Track, int] = {}
        meta: List[dict] = []

        def pid_of(cat: str) -> int:
            if cat not in cats:
                cats[cat] = len(cats) + 1
                meta.append({"ph": "M", "name": "process_name",
                             "pid": cats[cat], "tid": 0,
                             "args": {"name": cat}})
                try:
                    sort = self._PID_ORDER.index(cat)
                except ValueError:
                    sort = len(self._PID_ORDER)
                meta.append({"ph": "M", "name": "process_sort_index",
                             "pid": cats[cat], "tid": 0,
                             "args": {"sort_index": sort}})
            return cats[cat]

        def tid_of(track: Track) -> Tuple[int, int]:
            pid = pid_of(track[0])
            if track not in tids:
                tids[track] = len([t for t in tids if t[0] == track[0]]) + 1
                meta.append({"ph": "M", "name": "thread_name",
                             "pid": pid, "tid": tids[track],
                             "args": {"name": f"{track[0]} {track[1]}"}})
            return pid, tids[track]

        events: List[dict] = []
        for e in self._ring:
            pid, tid = tid_of(e.track)
            ts = e.tick * tick_us
            attrs = dict(e.attrs)
            if e.kind == "qdepth":
                events.append({"ph": "C", "name": "qdepth", "pid": pid,
                               "tid": tid, "ts": ts,
                               "args": {"depth": attrs.get("depth", 0)}})
            elif "dur" in attrs:
                dur = attrs.pop("dur")
                events.append({"ph": "X", "name": e.kind, "pid": pid,
                               "tid": tid, "ts": ts,
                               "dur": dur * tick_us, "args": attrs})
            else:
                events.append({"ph": "i", "name": e.kind, "pid": pid,
                               "tid": tid, "ts": ts, "s": "t",
                               "args": attrs})
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "otherData": {"clock": "sim_ticks",
                              "tick_us": tick_us,
                              "events_dropped": self.dropped_events}}

    def chrome_trace_json(self, *, tick_us: int = 1) -> str:
        """Deterministic serialization: sorted keys, no whitespace
        variance — two identically seeded runs export byte-identical
        traces (tested)."""
        return json.dumps(self.chrome_trace(tick_us=tick_us),
                          sort_keys=True, separators=(",", ":"))

    def export_chrome_trace(self, path: str, *, tick_us: int = 1) -> int:
        """Write the Perfetto JSON to ``path``; returns event count."""
        blob = self.chrome_trace_json(tick_us=tick_us)
        with open(path, "w") as f:
            f.write(blob)
        return len(self._ring)


# ---------------------------------------------------------------------------
# Wiring helpers: plug the existing subsystems into a registry/recorder
# ---------------------------------------------------------------------------


def register_fabric(reg: MetricRegistry, fabric, prefix: str = "fabric"):
    """Register any netsim topology (``Network`` / ``SwitchedFabric`` /
    ``ClosFabric``) under ``prefix`` — they all expose ``snapshot()``."""
    reg.register(prefix, fabric.snapshot)
    return reg


def register_node(reg: MetricRegistry, node, prefix: Optional[str] = None):
    """Register one ``RdmaNode``'s combined surface: host-side
    ``NodeStats``, the engine-carried per-QP counter totals (harvested
    at snapshot time — the epoch boundary, the only host sync they ever
    cost), flow control, RX credits and the retransmission buffer."""
    p = prefix if prefix is not None else f"node{node.node_id}"
    reg.register(p, node.snapshot)
    return reg


def register_recorder(reg: MetricRegistry, rec: FlightRecorder,
                      prefix: str = "flight"):
    reg.register(prefix, rec.snapshot)
    return reg


def instrument(fabric=None, nodes=(), recorder: Optional[FlightRecorder] = None,
               registry: Optional[MetricRegistry] = None
               ) -> Tuple[MetricRegistry, FlightRecorder]:
    """One-call observability: attach a flight recorder to the fabric
    and every node, register all their stats surfaces (plus the
    recorder itself) into a registry, and return ``(registry,
    recorder)``.  The canonical setup the docs/benches use:

        reg, rec = instrument(fabric=res.fabric,
                              nodes=[res.receiver] + res.senders)
    """
    rec = recorder if recorder is not None else FlightRecorder()
    reg = registry if registry is not None else MetricRegistry()
    if fabric is not None:
        fabric.attach_recorder(rec)
        register_fabric(reg, fabric)
    for node in nodes:
        node.attach_recorder(rec)
        register_node(reg, node)
    register_recorder(reg, rec)
    return reg, rec
