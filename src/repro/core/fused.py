"""Fused epoch core: whole simulator epochs inside one jitted loop.

Every per-tick construct of the Python simulator — the fabric's ingress
wire and drop-tail egress rings, RED/ECN mark state, the RDMA nodes'
retransmission slots, ACK-clocked flow-control ledgers and the RX
header-FSM tables — is packed into ONE flat int32 vector ("the blob")
and an entire epoch of network ticks runs inside a single jitted
``lax.while_loop`` with donated buffers.  The Python-object netsim
(`netsim.SwitchedFabric` / `netsim.Network`) stays the oracle: the
property suite (tests/test_fused_core.py) asserts the fused epoch is
bit-identical to per-tick stepping under loss / dup / ECN / reorder
schedules, for both go-back-N and selective-repeat RX modes.

Design
------
* ``try_pack(nodes)`` inspects the live simulation.  If every feature in
  play is one the in-graph twin models (see the gate list in
  ``try_pack``), it returns a ``_World`` — the blob plus the host-side
  plan needed to unpack.  Anything else returns ``None`` and the caller
  falls back to per-tick ``rdma.step_network`` — fused mode is a fast
  path, never a semantic fork.
* The *plan*: per directed flow (sender QP -> receiver QP), every packet
  that can possibly appear during the epoch is precomputed on the host
  (held retransmit slots, in-flight wire packets, and the fragments of
  still-queued flow-control chunks).  In-graph, a data packet is just
  ``(flow, plan_row)`` — payload bytes never touch the device; the DMA
  writes are replayed on the host at unpack from the recorded
  ``(accepted, address, order)`` columns.
* Randomness: loss / RED / jitter / reorder decisions replay the
  counter-keyed hash of ``repro.core.chaos`` — pure functions of
  ``(seed, purpose, tick, rank)`` that the sequential oracle and this
  vector core rank identically.
* The engine-counter contract of the telemetry plane is intact: the
  per-QP counter columns (``pipeline.COUNTER_FIELDS``) ride the blob
  and are harvested exactly once, at the epoch boundary.

The in-graph tick mirrors the oracle *sequentially* (nested
``fori_loop``s in exact oracle event order) — bit-identity is the gate;
the win is host<->device traffic, which drops from O(ticks) to O(1)
per epoch (see BENCH_sync_census.json before/after).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import chaos
from repro.core import netsim
from repro.core import packet as pk
from repro.core.pipeline import _STATE_FIELDS, _rx_decide

MASK = pk.PSN_MASK
SPAN = MASK + 1
HALF = MASK // 2
NEG = -(10 ** 9)             # "never happened" holdoff sentinel (rdma.py)
MAX_RETRIES = 16             # retransmit.RetransmissionBuffer.MAX_RETRIES
NAK_HOLDOFF = 8              # rdma.RdmaNode.NAK_HOLDOFF
CNP_HOLDOFF = 8              # rdma.RdmaNode.CNP_HOLDOFF
BIG = np.int32(2 ** 31 - 1)  # sort key for not-due wire slots

_LAST_OPS = (pk.WRITE_LAST, pk.WRITE_ONLY,
             pk.READ_RESP_LAST, pk.READ_RESP_ONLY)

_PC_BUCKETS = (8, 16, 32, 64, 128, 256, 512)
_CC_BUCKETS = (4, 8, 16, 32, 64, 128)
_W_BUCKETS = (64, 128, 256, 512, 1024)


def _bucket(n: int, opts) -> Optional[int]:
    for o in opts:
        if n <= o:
            return o
    return None


def _i32(x: int) -> int:
    """uint32 value -> the int32 with the same bit pattern (the blob is
    all-int32; unsigned thresholds are compared via bitcast in-graph)."""
    x = int(x) & 0xFFFFFFFF
    return x - (1 << 32) if x >= (1 << 31) else x


def _u32(x):
    """Bitcast an int32 lane back to uint32 for unsigned compares."""
    return lax.bitcast_convert_type(jnp.asarray(x, jnp.int32), jnp.uint32)


def _hash(seed_u32, tag: int, tick, idx):
    """In-graph twin of ``chaos.hash32`` (uint32 lanes)."""
    u = jnp.uint32
    x = (seed_u32
         ^ (u(tag) * u(0x9E3779B1))
         ^ (jnp.asarray(tick, jnp.int32).astype(jnp.uint32) * u(0x85EBCA77))
         ^ (jnp.asarray(idx, jnp.int32).astype(jnp.uint32) * u(0xC2B2AE3D)))
    x = x ^ (x >> u(16))
    x = x * u(0x7FEB352D)
    x = x ^ (x >> u(15))
    x = x * u(0x846CA68B)
    x = x ^ (x >> u(16))
    return x


# ---------------------------------------------------------------------------
# Blob layout
# ---------------------------------------------------------------------------

class _Layout:
    """Name -> (offset, shape) map over one flat int32 vector.  The
    layout is a pure function of the shape key, so the jitted epoch
    function (cached per shape key) slices it with static offsets."""

    def __init__(self, spec):
        self.index: Dict[str, Tuple[int, Tuple[int, ...], int]] = {}
        off = 0
        for name, shape in spec:
            n = 1
            for s in shape:
                n *= s
            self.index[name] = (off, tuple(shape), n)
            off += n
        self.size = off

    def pack(self, vals: Dict[str, object]) -> np.ndarray:
        vec = np.zeros(self.size, np.int32)
        for name, (off, shape, n) in self.index.items():
            v = vals.get(name)
            if v is None:
                continue
            a = np.asarray(v, np.int64).reshape(-1)
            if a.size != n:
                raise ValueError(f"{name}: got {a.size} values, want {n}")
            vec[off:off + n] = a.astype(np.int32)
        return vec

    def unpack_jnp(self, vec) -> Dict[str, jax.Array]:
        c = {}
        for name, (off, shape, n) in self.index.items():
            v = vec[off:off + n]
            c[name] = v.reshape(shape) if shape else v[0]
        return c

    def concat(self, c: Dict[str, jax.Array]) -> jax.Array:
        parts = []
        for name, (off, shape, n) in self.index.items():
            v = jnp.asarray(c[name], jnp.int32)
            parts.append(v.reshape(-1) if shape else v.reshape(1))
        return jnp.concatenate(parts)

    def get(self, vec_np: np.ndarray, name: str):
        off, shape, n = self.index[name]
        v = vec_np[off:off + n]
        return v.reshape(shape) if shape else int(v[0])


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Everything that decides trace shapes.  One jitted epoch function
    (and one layout) exists per distinct key (``make_epoch_fn`` is
    lru-cached on it)."""
    mode: str                 # "star" | "p2p"
    N: int                    # nodes
    P: int                    # star ports (0 for p2p)
    L: int                    # directed links (0 for star)
    G: int                    # delivery groups (= P or L)
    F: int                    # directed flows
    PC: int                   # plan rows per flow (bucketed)
    CC: int                   # pending chunks per flow (bucketed)
    WCAP: int                 # wire slots (bucketed)
    RCAP: int                 # egress ring depth (= queue_capacity)
    DEL: Tuple[int, ...]      # per-group delivery budget (static)
    LDST: Tuple[int, ...]     # per-link destination node (p2p)
    loss_on: bool
    ecn_on: bool
    jit_on: bool
    reo_on: bool
    wm_on: bool


def _layout_for(skey: ShapeKey) -> _Layout:
    N, P, L, G, F, PC, CC = (skey.N, skey.P, skey.L, skey.G, skey.F,
                             skey.PC, skey.CC)
    WCAP, RCAP = skey.WCAP, skey.RCAP
    S = ()                                    # scalar shape
    spec = [
        # -- globals ----------------------------------------------------
        ("now", S), ("steps", S), ("idle", S), ("abort", S),
        ("acc_ctr", S), ("wm_hit", S), ("max_ticks", S), ("idle_done", S),
        # -- flows ------------------------------------------------------
        ("f_snd", (F,)), ("f_sq", (F,)), ("f_rcv", (F,)), ("f_rq", (F,)),
        ("f_sr", (F,)), ("f_window", (F,)), ("f_gap_lag", (F,)),
        ("f_timeout", (F,)), ("f_base", (F,)), ("f_plan_len", (F,)),
        ("f_nchunks", (F,)), ("f_cursor", (F,)), ("f_next", (F,)),
        ("f_budget", (F,)), ("f_out", (F,)), ("f_tpassed_d", (F,)),
        ("f_last_nak", (F,)), ("f_last_nak_w", (F,)),
        ("f_last_gap", (F,)), ("f_last_gap_w", (F,)),
        ("f_last_cnp", (F,)), ("f_last_cnp_w", (F,)),
        ("f_wm", (F,)), ("f_wm_armed", (F,)), ("f_wm_thresh", (F,)),
        ("f_maxcred", (F,)), ("f_lastgid", (F,)),
        # -- plan -------------------------------------------------------
        ("p_op", (F, PC)), ("p_plen", (F, PC)), ("p_vaddr", (F, PC)),
        ("p_dlen", (F, PC)), ("p_ackreq", (F, PC)), ("p_rkey", (F, PC)),
        ("p_held", (F, PC)), ("p_retr", (F, PC)), ("p_dl", (F, PC)),
        ("p_acc", (F, PC)), ("p_aseq", (F, PC)), ("p_aaddr", (F, PC)),
        ("c_np", (F, CC)),
        # -- receiver RX rows (gathered QP-table rows, one per flow) ----
        ("rx_epsn", (F,)), ("rx_msn", (F,)), ("rx_bytes", (F,)),
        ("rx_cur", (F,)), ("rx_cred", (F,)), ("rx_rkey", (F,)),
        ("rx_rxbit", (F,)), ("rx_srf", (F,)),
        ("rx_acc", (F,)), ("rx_dup", (F,)), ("rx_ooo", (F,)),
        ("rx_cdrop", (F,)), ("rx_ecn", (F,)),
        # -- node stat deltas -------------------------------------------
        ("n_tx", (N,)), ("n_rx", (N,)), ("n_retx", (N,)),
        ("n_sacked", (N,)), ("n_cnptx", (N,)), ("n_cnprx", (N,)),
        # -- wire slots -------------------------------------------------
        ("w_valid", (WCAP,)), ("w_arr", (WCAP,)), ("w_seq", (WCAP,)),
        ("w_dst", (WCAP,)), ("w_flow", (WCAP,)), ("w_pidx", (WCAP,)),
        ("w_kind", (WCAP,)), ("w_ap", (WCAP,)), ("w_sack", (WCAP,)),
        # -- order tables -----------------------------------------------
        ("t_order", (F,)), ("cnp_ord", (G, F)),
    ]
    if skey.mode == "star":
        spec += [
            ("seq", S), ("injected_d", S), ("cseed", S), ("loss_t", S),
            ("kmin", S), ("kmax", S), ("csend", S), ("cpop", S),
            ("delay", (P,)), ("red_t", (RCAP + 1,)),
            ("pt_enq", (P,)), ("pt_del", (P,)), ("pt_tdrop", (P,)),
            ("pt_wdrop", (P,)), ("pt_ecn", (P,)), ("pt_maxd", (P,)),
            ("r_head", (P,)), ("r_len", (P,)),
            ("r_flow", (P, RCAP)), ("r_pidx", (P, RCAP)),
            ("r_kind", (P, RCAP)), ("r_ap", (P, RCAP)),
            ("r_sack", (P, RCAP)),
        ]
    else:
        spec += [
            ("l_seed", (L,)), ("l_loss_t", (L,)), ("l_reorder_t", (L,)),
            ("l_jitter", (L,)), ("l_lat", (L,)), ("l_seq", (L,)),
            ("l_sent_d", (L,)), ("l_drop_d", (L,)), ("l_cidx", (L,)),
            ("f_ldata", (F,)), ("f_lctrl", (F,)),
        ]
    return _Layout(spec)


@lru_cache(maxsize=None)
def _cached_layout(skey: ShapeKey) -> _Layout:
    return _layout_for(skey)


# ---------------------------------------------------------------------------
# Packing: live Python simulation -> blob (or None when not fusable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Flow:
    """Host-side view of one directed flow (sender QP -> receiver QP)."""
    idx: int
    snd: object                  # RdmaNode
    rcv: object
    sq: int                      # sender-local QPN
    rq: int                      # receiver-local QPN
    base: int                    # PSN of plan row 0
    plan: List[Optional[pk.Packet]]    # row -> packet template (or None)
    n_chunks: int
    window: int
    had_slot_key: bool           # retx.slots had the sq key at pack
    rx_prog0: int
    rx_prog_had_key: bool
    rx0: np.ndarray              # packed (13,) receiver table row


@dataclasses.dataclass
class _World:
    skey: ShapeKey
    layout: _Layout
    vec0: np.ndarray
    flows: List[_Flow]
    net: object
    link_keys: List[Tuple[int, int]]   # p2p only


def _ctrl_tuple(p: pk.Packet, flow: _Flow) -> Optional[Tuple[int, int, int]]:
    """Classify an in-flight control packet and verify it is exactly the
    packet the in-graph twin would reconstruct.  Returns (kind, ack_psn,
    sack) or None."""
    if p.opcode == pk.ACK:
        ref, kind = pk.make_ack(flow.sq, p.ack_psn, sack=p.sack_bits), 1
    elif p.opcode == pk.NAK:
        ref, kind = pk.make_ack(flow.sq, p.ack_psn, nak=True), 2
    elif p.opcode == pk.CNP:
        ref = pk.make_cnp(flow.sq, src_ip=flow.rcv.node_id, path_id=-1)
        kind = 3
    else:
        return None
    if not _pkt_eq(p, ref):
        return None
    return kind, int(p.ack_psn) & MASK, int(p.sack_bits)


def _pkt_eq(a: pk.Packet, b: pk.Packet) -> bool:
    for f in dataclasses.fields(pk.Packet):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if f.name == "payload":
            an = va is None or va.size == 0
            bn = vb is None or vb.size == 0
            if an != bn or (not an and not np.array_equal(va, vb)):
                return False
        elif va != vb:
            return False
    return True


def try_pack(nodes, max_ticks: int, idle_done: int,
             watermarks: Optional[Dict[Tuple[int, int], int]] = None
             ) -> Optional[_World]:
    """Inspect the live simulation; return a packed ``_World`` when every
    feature in play is modeled in-graph, else None (caller falls back to
    per-tick stepping).  Packing never mutates the Python objects."""
    if not nodes:
        return None
    net = nodes[0].net
    N = len(nodes)
    for i, nd in enumerate(nodes):
        if (nd.net is not net or nd.node_id != i
                or nd.services is not None or nd.sniffer is not None
                or nd.recorder is not None or nd.fc.rate is not None
                or nd._retx_staged or nd._fatal_qps or nd.qp_errors):
            return None

    link_keys: List[Tuple[int, int]] = []
    if type(net) is netsim.SwitchedFabric:
        mode = "star"
        cfg = net.cfg
        if (net.reducer is not None or net.recorder is not None
                or net.n_nodes != N
                or any(q.on_event is not None for q in net.egress)
                or any(d < 1 for d in net.delay)):
            return None
        if (cfg.loss_prob > 0 or cfg.ecn_kmax > 0) and cfg.chaos_seed is None:
            return None
        P, L, G = N, 0, N
        loss_on, ecn_on = cfg.loss_prob > 0, cfg.ecn_kmax > 0
        jit_on = reo_on = False
        RCAP = int(cfg.queue_capacity)
    elif type(net) is netsim.Network:
        mode = "p2p"
        if net.recorder is not None:
            return None
        link_keys = list(net.links)          # oracle delivery order
        links = [net.links[k] for k in link_keys]
        if not links:
            return None
        c0 = links[0].cfg
        for (a, b), lk in zip(link_keys, links):
            lc = lk.cfg
            if (lk.on_event is not None or a >= N or b >= N
                    or lc.latency_ticks < 1
                    or lc.loss_prob != c0.loss_prob
                    or lc.reorder_prob != c0.reorder_prob
                    or lc.jitter_ticks != c0.jitter_ticks
                    or (lc.chaos_seed is None) != (c0.chaos_seed is None)):
                return None
        loss_on, reo_on = c0.loss_prob > 0, c0.reorder_prob > 0
        jit_on = c0.jitter_ticks > 0
        if (loss_on or reo_on or jit_on) and c0.chaos_seed is None:
            return None
        P, L, G = 0, len(links), len(links)
        ecn_on = False
        RCAP = 1                              # unused; keep layout small
    else:
        return None

    # ---- enumerate directed flows -------------------------------------
    flows: List[_Flow] = []
    by_rcv: Dict[Tuple[int, int], _Flow] = {}
    by_snd: Dict[Tuple[int, int], _Flow] = {}
    for s in nodes:
        for sq in sorted(s._peer):
            dst = s._peer[sq]
            if not 0 <= dst < N:
                return None
            r = nodes[dst]
            rq = int(s.qp.tables.remote_qpn[sq])
            if (int(r.qp.tables.remote_qpn[rq]) != sq or s._sr != r._sr):
                return None
            fl = _Flow(idx=len(flows), snd=s, rcv=r, sq=sq, rq=rq,
                       base=0, plan=[], n_chunks=0,
                       window=int(s.fc.cfg.window),
                       had_slot_key=sq in s.retx.slots,
                       rx_prog0=r._rx_progress.get(rq, 0),
                       rx_prog_had_key=rq in r._rx_progress,
                       rx0=np.zeros(13, np.int64))
            flows.append(fl)
            by_rcv[(r.node_id, rq)] = fl
            by_snd[(s.node_id, sq)] = fl
    F = len(flows)
    if F == 0:
        return None

    # ---- collect every in-flight packet -------------------------------
    # (container, dst, arrival, seq) tuples; classification below
    inflight: List[Tuple[str, int, int, int, pk.Packet]] = []
    ring_content: List[List[pk.Packet]] = []
    if mode == "star":
        for arr, seq, dst, p in net._wire:
            inflight.append(("wire", dst, arr, seq, p))
        for port, q in enumerate(net.egress):
            pkts = []
            for item in q._q:
                p, meta = item
                if meta is not None:
                    return None
                pkts.append(p)
                inflight.append(("ring", port, 0, 0, p))
            ring_content.append(pkts)
    else:
        for li, lk in enumerate(links):
            for arr, seq, p in lk._heap:
                inflight.append(("wire", li, arr, seq, p))

    def _flow_of(p: pk.Packet, dst_node: int) -> Optional[Tuple[_Flow, int]]:
        if p.coll_tag or p.ecn or p.path_id != -1:
            return None
        if p.opcode in pk.PAYLOAD_OPS:
            fl = by_rcv.get((dst_node, p.qpn))
            return None if fl is None else (fl, 0)
        fl = by_snd.get((dst_node, p.qpn))
        if fl is None:
            return None
        ct = _ctrl_tuple(p, fl)
        return None if ct is None else (fl, ct[0])

    # map in-flight data packets onto their flow (psn -> packet)
    data_by_flow: List[Dict[int, pk.Packet]] = [dict() for _ in range(F)]
    for where, loc, arr, seq, p in inflight:
        dst_node = loc if mode == "star" else link_keys[loc][1]
        hit = _flow_of(p, dst_node)
        if hit is None:
            return None
        fl, kind = hit
        if kind == 0:
            prev = data_by_flow[fl.idx].setdefault(p.psn & MASK, p)
            if prev is not p and not _pkt_eq(prev, p):
                return None

    # ---- per-flow plan construction -----------------------------------
    tbl = [np.asarray(jnp.stack(
        [jnp.asarray(getattr(nd.rx_tables, f)) for f in _STATE_FIELDS]))
        for nd in nodes]
    chunk_rows: List[List[int]] = []
    for fl in flows:
        s, r, sq, rq = fl.snd, fl.rcv, fl.sq, fl.rq
        held = s.retx.slots.get(sq, {})
        for slot in held.values():
            if slot.packet.opcode not in pk.PAYLOAD_OPS:
                return None
        npsn = int(s.qp.tables.npsn[sq])
        psns = set(held) | set(data_by_flow[fl.idx])
        offs = [(npsn - psn) & MASK for psn in psns]
        if any(o == 0 or o > HALF for o in offs):
            return None
        base = npsn - (max(offs) if offs else 0)
        if base < 0:
            return None
        templates: List[Optional[pk.Packet]] = []
        for row in range(npsn - base):
            psn = base + row
            if psn in held:
                templates.append(held[psn].packet)
            elif psn in data_by_flow[fl.idx]:
                templates.append(data_by_flow[fl.idx][psn])
            else:
                templates.append(None)
        cur, npkts = npsn, []
        for n_req, item in s.fc.pending[sq]:
            kind, addr, data, coll = item
            if kind == "read" or coll is not None:
                return None
            pkts = pk.fragment_message(
                rq, cur, addr, s._remote_rkey[sq], data,
                op="write" if kind == "write" else "read_resp",
                mtu=s.mtu, src_ip=s.node_id,
                dst_ip=int(s.qp.tables.remote_ip[sq]),
                addr_per_pkt=s._sr)
            if len(pkts) != n_req:
                return None
            templates.extend(pkts)
            npkts.append(n_req)
            cur = (cur + n_req) & MASK
        if base + len(templates) >= SPAN:
            return None
        for row, t in enumerate(templates):
            if t is None:
                continue
            if (t.psn != base + row or t.opcode not in pk.PAYLOAD_OPS
                    or t.vaddr < 0 or t.vaddr + t.dma_len >= 2 ** 31
                    or t.payload_len > min(s.mtu, r.mtu)):
                return None
        for psn, p in data_by_flow[fl.idx].items():
            if not _pkt_eq(p, templates[psn - base]):
                return None
        fl.base, fl.plan, fl.n_chunks = base, templates, len(npkts)
        chunk_rows.append(npkts)
        # receiver-side invariants
        if (r.credits.credits[rq] != r.credits.max_credits
                or fl.rx_prog0 >= 2 ** 31 or r._buffer_for(rq) is None):
            return None
        row13 = tbl[r.node_id][:, rq].astype(np.int64)
        if bool(row13[_STATE_FIELDS.index("sr")]) != s._sr:
            return None
        fl.rx0 = row13
        if watermarks and (r.node_id, rq) in watermarks and s._sr:
            return None                       # watermark exit is GBN-only

    # ---- buckets / shape key ------------------------------------------
    PC = _bucket(max(max((len(fl.plan) for fl in flows)), 1), _PC_BUCKETS)
    CC = _bucket(max(max((fl.n_chunks for fl in flows)), 1), _CC_BUCKETS)
    n_wire = sum(1 for e in inflight if e[0] == "wire")
    WCAP = _bucket(n_wire + 2 * sum(fl.window for fl in flows)
                   + 2 * F + 16, _W_BUCKETS)
    if PC is None or CC is None or WCAP is None:
        return None
    if mode == "star":
        DEL = tuple(min(b, RCAP) for b in net.bandwidth)
        LDST: Tuple[int, ...] = ()
    else:
        DEL = tuple(min(lk.cfg.bandwidth_pkts_per_tick or (1 << 30), WCAP)
                    for lk in links)
        LDST = tuple(b for (_a, b) in link_keys)
    skey = ShapeKey(mode=mode, N=N, P=P, L=L, G=G, F=F, PC=PC, CC=CC,
                    WCAP=WCAP, RCAP=RCAP, DEL=DEL, LDST=LDST,
                    loss_on=loss_on, ecn_on=ecn_on, jit_on=jit_on,
                    reo_on=reo_on, wm_on=bool(watermarks))
    layout = _cached_layout(skey)

    # ---- blob values ---------------------------------------------------
    v: Dict[str, object] = {
        "now": net.now, "max_ticks": max_ticks, "idle_done": idle_done,
        "f_snd": [fl.snd.node_id for fl in flows],
        "f_sq": [fl.sq for fl in flows],
        "f_rcv": [fl.rcv.node_id for fl in flows],
        "f_rq": [fl.rq for fl in flows],
        "f_sr": [int(fl.snd._sr) for fl in flows],
        "f_window": [fl.window for fl in flows],
        "f_gap_lag": [fl.snd.sr_gap_lag for fl in flows],
        "f_timeout": [fl.snd.retx.timeout for fl in flows],
        "f_base": [fl.base for fl in flows],
        "f_plan_len": [len(fl.plan) for fl in flows],
        "f_nchunks": [fl.n_chunks for fl in flows],
        "f_budget": [fl.snd.fc.budget[fl.sq] for fl in flows],
        "f_out": [fl.snd.fc.outstanding[fl.sq] for fl in flows],
        "f_last_nak": [fl.snd._last_nak_resend.get(fl.sq, NEG)
                       for fl in flows],
        "f_last_gap": [fl.snd._last_gap_resend.get(fl.sq, NEG)
                       for fl in flows],
        "f_last_cnp": [fl.rcv._last_cnp_sent.get(fl.rq, NEG)
                       for fl in flows],
        "f_wm": [fl.rx_prog0 for fl in flows],
        "f_wm_armed": [int(bool(watermarks)
                           and (fl.rcv.node_id, fl.rq) in watermarks)
                       for fl in flows],
        "f_wm_thresh": [(watermarks or {}).get((fl.rcv.node_id, fl.rq), 0)
                        for fl in flows],
        "f_maxcred": [fl.rcv.credits.max_credits for fl in flows],
    }
    p_op = np.zeros((F, PC), np.int64)
    p_plen = np.zeros((F, PC), np.int64)
    p_vaddr = np.zeros((F, PC), np.int64)
    p_dlen = np.zeros((F, PC), np.int64)
    p_ackreq = np.zeros((F, PC), np.int64)
    p_rkey = np.zeros((F, PC), np.int64)
    p_held = np.zeros((F, PC), np.int64)
    p_retr = np.zeros((F, PC), np.int64)
    p_dl = np.zeros((F, PC), np.int64)
    p_aseq = np.full((F, PC), -1, np.int64)
    c_np = np.zeros((F, CC), np.int64)
    for fl, npkts in zip(flows, chunk_rows):
        held = fl.snd.retx.slots.get(fl.sq, {})
        for row, t in enumerate(fl.plan):
            if t is None:
                continue
            p_op[fl.idx, row] = t.opcode
            p_plen[fl.idx, row] = t.payload_len
            p_vaddr[fl.idx, row] = t.vaddr
            p_dlen[fl.idx, row] = t.dma_len
            p_ackreq[fl.idx, row] = int(t.ack_req)
            p_rkey[fl.idx, row] = t.rkey
        for psn, slot in held.items():
            row = psn - fl.base
            p_held[fl.idx, row] = 1
            p_retr[fl.idx, row] = slot.retries
            p_dl[fl.idx, row] = slot.deadline
        c_np[fl.idx, :len(npkts)] = npkts
        v["f_next"] = v.get("f_next", [])
    v["f_next"] = [int(fl.snd.qp.tables.npsn[fl.sq]) - fl.base
                   for fl in flows]
    v.update(p_op=p_op, p_plen=p_plen, p_vaddr=p_vaddr, p_dlen=p_dlen,
             p_ackreq=p_ackreq, p_rkey=p_rkey, p_held=p_held,
             p_retr=p_retr, p_dl=p_dl, p_aseq=p_aseq, c_np=c_np)
    rx_names = ("rx_epsn", "rx_msn", "rx_bytes", "rx_cur", "rx_cred",
                "rx_rkey", "rx_rxbit", "rx_srf", "rx_acc", "rx_dup",
                "rx_ooo", "rx_cdrop", "rx_ecn")
    rxm = np.stack([fl.rx0 for fl in flows], axis=1)    # (13, F)
    for k, name in enumerate(rx_names):
        v[name] = rxm[k]

    # wire slots
    wn = ("w_valid", "w_arr", "w_seq", "w_dst", "w_flow", "w_pidx",
          "w_kind", "w_ap", "w_sack")
    wv = {n: np.zeros(WCAP, np.int64) for n in wn}
    wi = 0
    for where, loc, arr, seq, p in inflight:
        if where != "wire":
            continue
        dst_node = loc if mode == "star" else link_keys[loc][1]
        fl, kind = _flow_of(p, dst_node)
        if kind == 0:
            pidx, ap, sack = (p.psn & MASK) - fl.base, 0, 0
        else:
            _, ap, sack = _ctrl_tuple(p, fl)
            pidx = 0
        wv["w_valid"][wi] = 1
        wv["w_arr"][wi] = arr
        wv["w_seq"][wi] = seq
        wv["w_dst"][wi] = loc
        wv["w_flow"][wi] = fl.idx
        wv["w_pidx"][wi] = pidx
        wv["w_kind"][wi] = kind
        wv["w_ap"][wi] = ap
        wv["w_sack"][wi] = sack
        wi += 1
    v.update(wv)

    # order tables
    v["t_order"] = sorted(range(F), key=lambda i: (flows[i].snd.node_id,
                                                   flows[i].sq))
    cnp_ord = np.full((G, F), -1, np.int64)
    for g in range(G):
        dst_node = g if mode == "star" else LDST[g]
        fs = sorted((fl for fl in flows if fl.rcv.node_id == dst_node),
                    key=lambda fl: fl.rq)
        for j, fl in enumerate(fs):
            cnp_ord[g, j] = fl.idx
    v["cnp_ord"] = cnp_ord

    if mode == "star":
        red = np.zeros(RCAP + 1, np.int64)
        if cfg.ecn_kmax > 0:
            for d in range(RCAP + 1):
                ramp = cfg.ecn_pmax * (d - cfg.ecn_kmin) / max(
                    cfg.ecn_kmax - cfg.ecn_kmin, 1)
                red[d] = _i32(chaos.u32_prob(min(max(ramp, 0.0), 1.0)))
        v.update(
            seq=net._seq, cseed=_i32(cfg.chaos_seed or 0),
            loss_t=_i32(chaos.u32_prob(cfg.loss_prob)),
            kmin=cfg.ecn_kmin, kmax=cfg.ecn_kmax,
            delay=net.delay, red_t=red,
            pt_maxd=[st.max_depth for st in net.port_stats],
            r_len=[len(q) for q in ring_content],
        )
        rn = ("r_flow", "r_pidx", "r_kind", "r_ap", "r_sack")
        rv = {n: np.zeros((P, RCAP), np.int64) for n in rn}
        for port, pkts in enumerate(ring_content):
            for j, p in enumerate(pkts):
                fl, kind = _flow_of(p, port)
                if kind == 0:
                    pidx, ap, sack = (p.psn & MASK) - fl.base, 0, 0
                else:
                    _, ap, sack = _ctrl_tuple(p, fl)
                    pidx = 0
                rv["r_flow"][port, j] = fl.idx
                rv["r_pidx"][port, j] = pidx
                rv["r_kind"][port, j] = kind
                rv["r_ap"][port, j] = ap
                rv["r_sack"][port, j] = sack
        v.update(rv)
    else:
        v.update(
            l_seed=[_i32(lk.cfg.chaos_seed or 0) for lk in links],
            l_loss_t=[_i32(chaos.u32_prob(lk.cfg.loss_prob))
                      for lk in links],
            l_reorder_t=[_i32(chaos.u32_prob(lk.cfg.reorder_prob))
                         for lk in links],
            l_jitter=[lk.cfg.jitter_ticks for lk in links],
            l_lat=[lk.cfg.latency_ticks for lk in links],
            l_seq=[lk._seq for lk in links],
            f_ldata=[link_keys.index((fl.snd.node_id, fl.rcv.node_id))
                     for fl in flows],
            f_lctrl=[link_keys.index((fl.rcv.node_id, fl.snd.node_id))
                     for fl in flows],
        )

    vec0 = layout.pack(v)
    return _World(skey=skey, layout=layout, vec0=vec0, flows=flows,
                  net=net, link_keys=link_keys)


# ---------------------------------------------------------------------------
# The jitted epoch graph
# ---------------------------------------------------------------------------

def _up(c, **kw):
    d = dict(c)
    d.update(kw)
    return d


@lru_cache(maxsize=None)
def make_epoch_fn(skey: ShapeKey):
    """Build (and cache, per shape key) the jitted blob -> blob epoch
    function.  The in-graph tick mirrors the Python oracle *in exact
    event order* via nested ``fori_loop``s; the payoff is that the
    entire epoch is ONE device program with ONE donated input and ONE
    output — host<->device traffic no longer scales with ticks."""
    layout = _cached_layout(skey)
    star = skey.mode == "star"
    N, F, PC, CC = skey.N, skey.F, skey.PC, skey.CC
    WCAP, RCAP, G = skey.WCAP, skey.RCAP, skey.G
    ARPC = jnp.arange(PC, dtype=jnp.int32)
    I32 = partial(jnp.asarray, dtype=jnp.int32)

    # ---- wire / ring primitives ---------------------------------------
    def _wire_push(c, arr, loc, seqv, f, kind, pidx, ap, sack):
        free = jnp.argmin(c["w_valid"])
        c = _up(c, abort=c["abort"] | c["w_valid"][free],
                w_valid=c["w_valid"].at[free].set(1),
                w_arr=c["w_arr"].at[free].set(arr),
                w_seq=c["w_seq"].at[free].set(seqv),
                w_dst=c["w_dst"].at[free].set(loc),
                w_flow=c["w_flow"].at[free].set(f),
                w_pidx=c["w_pidx"].at[free].set(pidx),
                w_kind=c["w_kind"].at[free].set(kind),
                w_ap=c["w_ap"].at[free].set(ap),
                w_sack=c["w_sack"].at[free].set(sack))
        return c

    def _ring_enq(c, dst, f, kind, pidx, ap, sack):
        depth = c["r_len"][dst]

        def drop(c):
            return _up(c, pt_tdrop=c["pt_tdrop"].at[dst].add(1))

        def enq(c):
            slot = (c["r_head"][dst] + depth) % RCAP
            return _up(
                c,
                r_flow=c["r_flow"].at[dst, slot].set(f),
                r_pidx=c["r_pidx"].at[dst, slot].set(pidx),
                r_kind=c["r_kind"].at[dst, slot].set(kind),
                r_ap=c["r_ap"].at[dst, slot].set(ap),
                r_sack=c["r_sack"].at[dst, slot].set(sack),
                r_len=c["r_len"].at[dst].add(1),
                pt_enq=c["pt_enq"].at[dst].add(1),
                pt_maxd=c["pt_maxd"].at[dst].set(
                    jnp.maximum(c["pt_maxd"][dst], depth + 1)))
        return lax.cond(depth >= RCAP, drop, enq, c)

    # ---- transmit (mirrors net.send called from RdmaNode._send) -------
    def _send(c, src, f, kind, pidx, ap, sack):
        c = _up(c, n_tx=c["n_tx"].at[src].add(1))
        if star:
            dst = jnp.where(kind == 0, c["f_rcv"][f], c["f_snd"][f])
            c = _up(c, injected_d=c["injected_d"] + 1)

            def push(c):
                seqv = c["seq"] + 1
                c = _up(c, seq=seqv)
                return _wire_push(c, c["now"] + c["delay"][src], dst,
                                  seqv, f, kind, pidx, ap, sack)
            if skey.loss_on:
                h = _hash(_u32(c["cseed"]), chaos.TAG_LOSS,
                          c["now"], c["csend"])
                lost = h < _u32(c["loss_t"])
                c = _up(c, csend=c["csend"] + 1)
                c = lax.cond(
                    lost,
                    lambda c: _up(c, pt_wdrop=c["pt_wdrop"].at[dst].add(1)),
                    push, c)
            else:
                c = push(c)
        else:
            link = jnp.where(kind == 0, c["f_ldata"][f], c["f_lctrl"][f])
            c = _up(c, l_sent_d=c["l_sent_d"].at[link].add(1))
            rank = c["l_cidx"][link]
            c = _up(c, l_cidx=c["l_cidx"].at[link].add(1))
            seed = _u32(c["l_seed"][link])

            def push(c):
                delay = c["l_lat"][link]
                if skey.jit_on:
                    jit = _hash(seed, chaos.TAG_JITTER, c["now"], rank) % (
                        c["l_jitter"][link] + 1).astype(jnp.uint32)
                    delay = delay + jit.astype(jnp.int32)
                if skey.reo_on:
                    hit = _hash(seed, chaos.TAG_REORDER, c["now"],
                                rank) < _u32(c["l_reorder_t"][link])
                    extra = jnp.int32(1) + (
                        _hash(seed, chaos.TAG_RDELAY, c["now"], rank)
                        % jnp.uint32(7)).astype(jnp.int32)
                    delay = delay + jnp.where(hit, extra, 0)
                seqv = c["l_seq"][link] + 1
                c = _up(c, l_seq=c["l_seq"].at[link].set(seqv))
                return _wire_push(c, c["now"] + delay, link, seqv,
                                  f, kind, pidx, ap, sack)
            if skey.loss_on:
                lost = _hash(seed, chaos.TAG_LOSS, c["now"],
                             rank) < _u32(c["l_loss_t"][link])
                c = lax.cond(
                    lost,
                    lambda c: _up(c, l_drop_d=c["l_drop_d"].at[link].add(1)),
                    push, c)
            else:
                c = push(c)
        return c

    def _send_data(c, f, row):
        return _send(c, c["f_snd"][f], f, I32(0), row, I32(0), I32(0))

    def _send_ctrl(c, f, kind, ap, sack):
        return _send(c, c["f_rcv"][f], f, kind, I32(0), ap, sack)

    # ---- retransmit bump (retransmit._bump + rdma._send_retx) ---------
    def _bump_send(c, f, row):
        r = c["p_retr"][f, row] + 1
        c = _up(c, p_retr=c["p_retr"].at[f, row].set(r))
        exh = r > MAX_RETRIES
        c = _up(c, abort=c["abort"] | exh.astype(jnp.int32))

        def fire(c):
            dl = c["now"] + c["f_timeout"][f] * jnp.left_shift(
                jnp.int32(1), jnp.minimum(r, 4))
            c = _up(c, p_dl=c["p_dl"].at[f, row].set(dl),
                    n_retx=c["n_retx"].at[c["f_snd"][f]].add(1))
            return _send_data(c, f, row)
        return lax.cond(exh, lambda c: c, fire, c)

    # ---- control-plane handlers ---------------------------------------
    def _on_ack(c, f, ap, sack):
        psn_row = (c["f_base"][f] + ARPC) & MASK
        held = c["p_held"][f] > 0
        # cumulative release (retransmit.ack): everything at or behind ap
        rel1 = held & (((ap - psn_row) & MASK) <= HALF)
        n1 = jnp.sum(rel1.astype(jnp.int32))
        held1 = held & ~rel1
        # selective release (retransmit.sack_release): bit j>=1 -> ap+1+j
        sacknz = sack != 0
        off2 = (psn_row - ap - 1) & MASK
        inb = (off2 >= 1) & (off2 <= 31)
        bitv = jnp.bitwise_and(
            lax.shift_right_logical(sack, jnp.where(inb, off2, 0)), 1)
        rel2 = held1 & inb & (bitv > 0) & sacknz
        n2 = jnp.sum(rel2.astype(jnp.int32))
        held2 = held1 & ~rel2
        anyrel = (n1 > 0) | (n2 > 0)
        c = _up(c,
                p_held=c["p_held"].at[f].set(held2.astype(jnp.int32)),
                p_retr=c["p_retr"].at[f].set(
                    jnp.where(held2 & anyrel, 0, c["p_retr"][f])),
                n_sacked=c["n_sacked"].at[c["f_snd"][f]].add(n2))
        # SACK-driven gap resend (rdma._maybe_gap_resend)
        do_gap = sacknz & ~((c["now"] - c["f_last_gap"][f]) < NAK_HOLDOFF)
        bl = (jnp.int32(32) - lax.clz(_u32(sack)).astype(jnp.int32))
        hi = (ap + bl) & MASK
        offg = (psn_row - ap) & MASK
        lag = (hi - psn_row) & MASK
        gmask = (held2 & (offg > 0) & (offg <= HALF) & (lag <= HALF)
                 & (lag >= c["f_gap_lag"][f]) & do_gap)
        c = lax.cond(
            jnp.any(gmask),
            lambda c: _up(c,
                          f_last_gap=c["f_last_gap"].at[f].set(c["now"]),
                          f_last_gap_w=c["f_last_gap_w"].at[f].set(1)),
            lambda c: c, c)
        c = lax.fori_loop(
            0, PC,
            lambda row, c: lax.cond(gmask[row],
                                    lambda c: _bump_send(c, f, row),
                                    lambda c: c, c),
            c)
        # ACK-clocked flow control (flow_control.ack + _drain + dispatch)
        rel = jnp.maximum(n1 + n2, 1)
        out0 = jnp.maximum(0, c["f_out"][f] - rel)
        bud0 = jnp.minimum(c["f_window"][f], c["f_budget"][f] + rel)
        cur0, nch, row_np = c["f_cursor"][f], c["f_nchunks"][f], c["c_np"][f]

        def drain_body(k, st):
            go, bud, taken, tot = st
            idx = jnp.minimum(cur0 + k, CC - 1)
            fit = go & ((cur0 + k) < nch) & (row_np[idx] <= bud)
            return (fit, jnp.where(fit, bud - row_np[idx], bud),
                    taken + fit.astype(jnp.int32),
                    tot + jnp.where(fit, row_np[idx], 0))
        _go, bud1, taken, tot = lax.fori_loop(
            0, CC, drain_body,
            (jnp.asarray(True), bud0, I32(0), I32(0)))
        nxt0 = c["f_next"][f]
        c = _up(c,
                f_cursor=c["f_cursor"].at[f].add(taken),
                f_next=c["f_next"].at[f].add(tot),
                f_out=c["f_out"].at[f].set(out0 + tot),
                f_budget=c["f_budget"].at[f].set(bud1),
                f_tpassed_d=c["f_tpassed_d"].at[f].add(taken))

        def disp_body(k, c):
            def fire(c):
                row = nxt0 + k
                c = _up(c, p_held=c["p_held"].at[f, row].set(1),
                        p_retr=c["p_retr"].at[f, row].set(0),
                        p_dl=c["p_dl"].at[f, row].set(
                            c["now"] + c["f_timeout"][f]))
                return _send_data(c, f, row)
            return lax.cond(k < tot, fire, lambda c: c, c)
        return lax.fori_loop(0, PC, disp_body, c)

    def _on_nak(c, f, ap):
        skip = (c["now"] - c["f_last_nak"][f]) < NAK_HOLDOFF

        def doit(c):
            c = _up(c, f_last_nak=c["f_last_nak"].at[f].set(c["now"]),
                    f_last_nak_w=c["f_last_nak_w"].at[f].set(1))
            expected = (ap + 1) & MASK
            psn_row = (c["f_base"][f] + ARPC) & MASK
            mask = (c["p_held"][f] > 0) & (
                ((psn_row - expected) & MASK) <= HALF)
            return lax.fori_loop(
                0, PC,
                lambda row, c: lax.cond(mask[row],
                                        lambda c: _bump_send(c, f, row),
                                        lambda c: c, c),
                c)
        return lax.cond(skip, lambda c: c, doit, c)

    def _on_cnp(c, f, _ap):
        return _up(c, n_cnprx=c["n_cnprx"].at[c["f_snd"][f]].add(1))

    # ---- one delivered batch through one node (rdma.on_packets) -------
    def _process_batch(c, g, dst, buf, B):
        bv, bf, bp_, bk, ba, bs, be = buf
        c = _up(c, n_rx=c["n_rx"].at[dst].add(jnp.sum(bv)))

        # pass A: control packets, batch order
        def ctrl_body(i, c):
            def do(c):
                f = bf[i]
                return lax.switch(
                    bk[i] - 1,
                    [lambda c: _on_ack(c, f, ba[i], bs[i]),
                     lambda c: _on_nak(c, f, ba[i]),
                     lambda c: _on_cnp(c, f, ba[i])],
                    c)
            return lax.cond((bv[i] > 0) & (bk[i] > 0), do, lambda c: c, c)
        c = lax.fori_loop(0, B, ctrl_body, c)

        # pass E: data packets through the RX decide FSM, batch order.
        # on_packets copies the WHOLE host credit column into the table
        # before running the engine on a data-bearing batch; the host
        # ledger is back at max between batches (every accept replenishes
        # what the engine debited — see the invariant note in try_pack),
        # so the copy is a column-wide reset to max for this node.
        anydata = jnp.sum((bv > 0) & (bk == 0)) > 0
        c = _up(c, rx_cred=jnp.where(
            anydata & (c["f_rcv"] == dst), c["f_maxcred"], c["rx_cred"]))

        def data_body(i, st):
            def do(st):
                c, ecn_f, o_ack, o_ap, o_sk, o_nak = st
                f, pidx = bf[i], bp_[i]
                state = {
                    "epsn": c["rx_epsn"][f], "msn": c["rx_msn"][f],
                    "bytes_left": c["rx_bytes"][f],
                    "cur_vaddr": c["rx_cur"][f],
                    "credits": c["rx_cred"][f], "rkey": c["rx_rkey"][f],
                    "rxbit": c["rx_rxbit"][f], "sr": c["rx_srf"][f],
                    "acc_cnt": c["rx_acc"][f], "dup_cnt": c["rx_dup"][f],
                    "ooo_cnt": c["rx_ooo"][f],
                    "cdrop_cnt": c["rx_cdrop"][f],
                    "ecn_tot": c["rx_ecn"][f]}
                p = {"qpn": c["f_rq"][f], "opcode": c["p_op"][f, pidx],
                     "psn": (c["f_base"][f] + pidx) & MASK,
                     "plen": c["p_plen"][f, pidx],
                     "vaddr": c["p_vaddr"][f, pidx],
                     "dma_len": c["p_dlen"][f, pidx],
                     "ack_req": c["p_ackreq"][f, pidx], "ecn": be[i],
                     "rkey": c["p_rkey"][f, pidx], "valid": jnp.int32(1)}
                ns, out = _rx_decide(state, p)
                c = _up(c,
                        rx_epsn=c["rx_epsn"].at[f].set(ns["epsn"]),
                        rx_msn=c["rx_msn"].at[f].set(ns["msn"]),
                        rx_bytes=c["rx_bytes"].at[f].set(
                            jnp.asarray(ns["bytes_left"], jnp.int32)),
                        rx_cur=c["rx_cur"].at[f].set(
                            jnp.asarray(ns["cur_vaddr"], jnp.int32)),
                        rx_cred=c["rx_cred"].at[f].set(ns["credits"]),
                        rx_rxbit=c["rx_rxbit"].at[f].set(ns["rxbit"]),
                        rx_acc=c["rx_acc"].at[f].set(ns["acc_cnt"]),
                        rx_dup=c["rx_dup"].at[f].set(ns["dup_cnt"]),
                        rx_ooo=c["rx_ooo"].at[f].set(ns["ooo_cnt"]),
                        rx_cdrop=c["rx_cdrop"].at[f].set(ns["cdrop_cnt"]),
                        rx_ecn=c["rx_ecn"].at[f].set(ns["ecn_tot"]),
                        abort=c["abort"] | out["rkey_err"].astype(jnp.int32))
                ecn_f = ecn_f.at[f].add(out["ecn_echo"].astype(jnp.int32))

                def rec(c):
                    aseq = c["acc_ctr"]
                    dma_a = jnp.asarray(out["dma_addr"], jnp.int32)
                    wm = jnp.maximum(c["f_wm"][f], dma_a + out["dma_len"])
                    return _up(
                        c, acc_ctr=aseq + 1,
                        p_acc=c["p_acc"].at[f, pidx].set(1),
                        p_aseq=c["p_aseq"].at[f, pidx].set(aseq),
                        p_aaddr=c["p_aaddr"].at[f, pidx].set(dma_a),
                        f_wm=c["f_wm"].at[f].set(
                            jnp.where(c["rx_srf"][f] > 0,
                                      c["f_wm"][f], wm)))
                c = lax.cond(out["accept"], rec, lambda c: c, c)
                return (c, ecn_f,
                        o_ack.at[i].set(out["send_ack"].astype(jnp.int32)),
                        o_ap.at[i].set(out["ack_psn"]),
                        o_sk.at[i].set(out["sack"]),
                        o_nak.at[i].set(out["send_nak"].astype(jnp.int32)))
            return lax.cond((bv[i] > 0) & (bk[i] == 0), do,
                            lambda st: st, st)
        c, ecn_f, o_ack, o_ap, o_sk, o_nak = lax.fori_loop(
            0, B, data_body,
            (c, jnp.zeros(F, jnp.int32), jnp.zeros(B, jnp.int32),
             jnp.zeros(B, jnp.int32), jnp.zeros(B, jnp.int32),
             jnp.zeros(B, jnp.int32)))

        # CNP emission (rdma._emit_cnps): QPN-ascending, before the ACKs
        if skey.ecn_on:
            def cnp_body(k, c):
                fidx = c["cnp_ord"][g, k]
                f = jnp.maximum(fidx, 0)

                def do(c):
                    def fire(c):
                        c = _up(
                            c,
                            f_last_cnp=c["f_last_cnp"].at[f].set(c["now"]),
                            f_last_cnp_w=c["f_last_cnp_w"].at[f].set(1),
                            n_cnptx=c["n_cnptx"].at[dst].add(1))
                        return _send_ctrl(c, f, I32(3), I32(0), I32(0))
                    hold = (c["now"] - c["f_last_cnp"][f]) < CNP_HOLDOFF
                    return lax.cond(hold, lambda c: c, fire, c)
                return lax.cond((fidx >= 0) & (ecn_f[f] > 0),
                                do, lambda c: c, c)
            c = lax.fori_loop(0, F, cnp_body, c)

        # pass D: ACK / NAK responses, batch order
        def resp_body(i, c):
            f = bf[i]
            ds = (bv[i] > 0) & (bk[i] == 0)
            c = lax.cond(
                ds & (o_ack[i] > 0),
                lambda c: _send_ctrl(c, f, I32(1), o_ap[i], o_sk[i]),
                lambda c: c, c)
            return lax.cond(
                ds & (o_nak[i] > 0),
                lambda c: _send_ctrl(c, f, I32(2), o_ap[i], I32(0)),
                lambda c: c, c)
        return lax.fori_loop(0, B, resp_body, c)

    # ---- one network tick (netsim.tick + rdma.step_network) -----------
    def _wire_due_perm(c, due):
        """Pop order of the wire heap: (arrival, seq) lexicographic."""
        perm1 = jnp.argsort(jnp.where(due, c["w_seq"], BIG))
        key2 = jnp.where(due, c["w_arr"], BIG)[perm1]
        return perm1[jnp.argsort(key2, stable=True)]

    def _tick(c):
        c = _up(c, now=c["now"] + 1)
        if star:
            if skey.loss_on or skey.ecn_on:
                c = _up(c, csend=I32(0), cpop=I32(0))
            # phase 1: due wire packets land in egress rings
            due = (c["w_valid"] > 0) & (c["w_arr"] <= c["now"])
            perm = _wire_due_perm(c, due)
            n_due = jnp.sum(due.astype(jnp.int32))

            def pop_body(i, c):
                def do(c):
                    s = perm[i]
                    c = _up(c, w_valid=c["w_valid"].at[s].set(0))
                    return _ring_enq(c, c["w_dst"][s], c["w_flow"][s],
                                     c["w_kind"][s], c["w_pidx"][s],
                                     c["w_ap"][s], c["w_sack"][s])
                return lax.cond(i < n_due, do, lambda c: c, c)
            c = lax.fori_loop(0, WCAP, pop_body, c)
            # phase 2: drain each port, feed the batch to its node
            for port in range(skey.P):
                B = skey.DEL[port]
                if B == 0:
                    continue
                len0, head0 = c["r_len"][port], c["r_head"][port]
                n_pop = jnp.minimum(B, len0)

                def drain_body(j, st, port=port, len0=len0, head0=head0,
                               n_pop=n_pop):
                    c, bv, bf, bp_, bk, ba, bs, be = st
                    active = j < n_pop
                    slot = (head0 + j) % RCAP
                    if skey.ecn_on:
                        depth = len0 - j
                        rank = c["cpop"]
                        c = _up(c, cpop=c["cpop"]
                                + jnp.where(active, 1, 0))
                        h = _hash(_u32(c["cseed"]), chaos.TAG_RED,
                                  c["now"], rank)
                        mark = active & (
                            (depth >= c["kmax"])
                            | ((depth > c["kmin"])
                               & (h < _u32(c["red_t"][depth]))))
                        c = _up(c, pt_ecn=c["pt_ecn"].at[port].add(
                            mark.astype(jnp.int32)))
                        be = be.at[j].set(mark.astype(jnp.int32))
                    a32 = active.astype(jnp.int32)
                    return (c,
                            bv.at[j].set(a32),
                            bf.at[j].set(a32 * c["r_flow"][port, slot]),
                            bp_.at[j].set(a32 * c["r_pidx"][port, slot]),
                            bk.at[j].set(a32 * c["r_kind"][port, slot]),
                            ba.at[j].set(a32 * c["r_ap"][port, slot]),
                            bs.at[j].set(a32 * c["r_sack"][port, slot]),
                            be)
                z = jnp.zeros(B, jnp.int32)
                c, bv, bf, bp_, bk, ba, bs, be = lax.fori_loop(
                    0, B, drain_body, (c, z, z, z, z, z, z, z))
                c = _up(c,
                        r_head=c["r_head"].at[port].set(
                            (head0 + n_pop) % RCAP),
                        r_len=c["r_len"].at[port].add(-n_pop),
                        pt_del=c["pt_del"].at[port].add(n_pop))
                c = _process_batch(c, port, port,
                                   (bv, bf, bp_, bk, ba, bs, be), B)
        else:
            if skey.loss_on or skey.jit_on or skey.reo_on:
                c = _up(c, l_cidx=jnp.zeros(skey.L, jnp.int32))
            # per-link deliver + node batch, link order
            for li in range(skey.L):
                B = skey.DEL[li]
                due = ((c["w_valid"] > 0) & (c["w_arr"] <= c["now"])
                       & (c["w_dst"] == li))
                perm = _wire_due_perm(c, due)
                n_take = jnp.minimum(jnp.sum(due.astype(jnp.int32)), B)

                def take_body(j, st, n_take=n_take, perm=perm):
                    c, bv, bf, bp_, bk, ba, bs = st
                    active = j < n_take
                    s = perm[j]
                    c = lax.cond(
                        active,
                        lambda c: _up(c,
                                      w_valid=c["w_valid"].at[s].set(0)),
                        lambda c: c, c)
                    a32 = active.astype(jnp.int32)
                    return (c,
                            bv.at[j].set(a32),
                            bf.at[j].set(a32 * c["w_flow"][s]),
                            bp_.at[j].set(a32 * c["w_pidx"][s]),
                            bk.at[j].set(a32 * c["w_kind"][s]),
                            ba.at[j].set(a32 * c["w_ap"][s]),
                            bs.at[j].set(a32 * c["w_sack"][s]))
                z = jnp.zeros(B, jnp.int32)
                c, bv, bf, bp_, bk, ba, bs = lax.fori_loop(
                    0, B, take_body, (c, z, z, z, z, z, z))
                c = _process_batch(c, li, skey.LDST[li],
                                   (bv, bf, bp_, bk, ba, bs, z), B)

        # phase 3: retransmission timers (rdma.tick, node x QPN order)
        def timer_flow(k, c):
            f = c["t_order"][k]

            def row_body(row, c):
                due = ((c["p_held"][f, row] > 0)
                       & (c["now"] >= c["p_dl"][f, row]))
                return lax.cond(due, lambda c: _bump_send(c, f, row),
                                lambda c: c, c)
            return lax.fori_loop(0, PC, row_body, c)
        c = lax.fori_loop(0, F, timer_flow, c)

        # phase 4: idle / watermark accounting (rdma.run_network)
        pending = (jnp.any(c["w_valid"] > 0) | jnp.any(c["p_held"] > 0)
                   | jnp.any(c["f_cursor"] < c["f_nchunks"]))
        if star:
            pending = pending | jnp.any(c["r_len"] > 0)
        c = _up(c, idle=jnp.where(pending, 0, c["idle"] + 1),
                steps=c["steps"] + 1)
        if skey.wm_on:
            hit = jnp.any((c["f_wm_armed"] > 0)
                          & (c["f_wm"] >= c["f_wm_thresh"]))
            c = _up(c, wm_hit=hit.astype(jnp.int32))
        return c

    def _cond(c):
        return ((c["abort"] == 0) & (c["wm_hit"] == 0)
                & (c["idle"] < c["idle_done"])
                & (c["steps"] < c["max_ticks"]))

    def epoch(vec):
        c = layout.unpack_jnp(vec)
        c = lax.while_loop(_cond, _tick, c)
        return layout.concat(c)

    return jax.jit(epoch, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Unpacking: blob -> live Python simulation
# ---------------------------------------------------------------------------

import collections
import heapq

from repro.core.retransmit import _Slot

_RX_NAMES = ("rx_epsn", "rx_msn", "rx_bytes", "rx_cur", "rx_cred",
             "rx_rkey", "rx_rxbit", "rx_srf", "rx_acc", "rx_dup",
             "rx_ooo", "rx_cdrop", "rx_ecn")


def _rebuild_pkt(fl: _Flow, kind: int, pidx: int, ap: int,
                 sack: int) -> pk.Packet:
    if kind == 0:
        return fl.plan[pidx].clone()
    if kind == 1:
        return pk.make_ack(fl.sq, ap, sack=sack)
    if kind == 2:
        return pk.make_ack(fl.sq, ap, nak=True)
    return pk.make_cnp(fl.sq, src_ip=fl.rcv.node_id, path_id=-1)


def _apply(world: _World, out: np.ndarray, nodes) -> None:
    """Write the epoch's final blob back into the Python objects,
    reproducing exactly the state the per-tick oracle would have."""
    lay, flows, skey = world.layout, world.flows, world.skey
    g = lambda name: lay.get(out, name)               # noqa: E731
    g0 = lambda name: lay.get(world.vec0, name)       # noqa: E731
    star = skey.mode == "star"

    held, retr, dl = g("p_held"), g("p_retr"), g("p_dl")
    acc, aseq, aaddr = g("p_acc"), g("p_aseq"), g("p_aaddr")
    nextv, next0, cur = g("f_next"), g0("f_next"), g("f_cursor")
    rxf = {n: g(n) for n in _RX_NAMES}

    # ---- DMA replay (+ SR interval merge), global acceptance order ----
    recs = []
    for fl in flows:
        for row in np.nonzero(acc[fl.idx])[0]:
            recs.append((int(aseq[fl.idx, row]), fl.idx, int(row)))
    recs.sort()
    for _s, fi, row in recs:
        fl = world.flows[fi]
        t = fl.plan[row]
        a, ln = int(aaddr[fl.idx, row]), t.payload_len
        buf = fl.rcv._buffer_for(fl.rq)
        if ln:
            buf[a:a + ln] = t.payload[:ln]
        if fl.snd._sr:
            fl.rcv._sr_note_progress(fl.rq, a, ln)

    for fl in flows:
        s, r, sq, rq, i = fl.snd, fl.rcv, fl.sq, fl.rq, fl.idx
        accd = int(rxf["rx_acc"][i]) - int(fl.rx0[8])
        dupd = int(rxf["rx_dup"][i]) - int(fl.rx0[9])
        oood = int(rxf["rx_ooo"][i]) - int(fl.rx0[10])
        cdropd = int(rxf["rx_cdrop"][i]) - int(fl.rx0[11])
        ecnd = int(rxf["rx_ecn"][i]) - int(fl.rx0[12])

        # receiver: progress watermark + message completions
        last_rows = [row for row in np.nonzero(acc[i])[0]
                     if fl.plan[row].opcode in _LAST_OPS]
        if s._sr:
            lst = list(r._sr_pending_last.get(rq, []))
            lst += [fl.base + int(row) for row in
                    sorted(last_rows, key=lambda rr: int(aseq[i, rr]))]
            if lst:
                epsn = int(rxf["rx_epsn"][i])
                done = [ps for ps in lst if ((ps - epsn) % SPAN) > HALF]
                rest = [ps for ps in lst if ((ps - epsn) % SPAN) <= HALF]
                if done:
                    r._completions[rq] = r._completions.get(rq, 0) \
                        + len(done)
                if rest:
                    r._sr_pending_last[rq] = rest
                else:
                    r._sr_pending_last.pop(rq, None)
        else:
            if accd > 0:
                r._rx_progress[rq] = int(g("f_wm")[i])
            if last_rows:
                r._completions[rq] = r._completions.get(rq, 0) \
                    + len(last_rows)

        # receiver: credit ledger (note_accepted/note_dropped/replenish)
        r.credits.accepted += accd
        r.credits.accepted_per_qp[rq] += accd
        r.credits.granted += accd
        r.credits.dropped_no_credit += cdropd
        r.credits.dropped_per_qp[rq] += cdropd

        # receiver: per-QP node stats driven by the engine verdicts
        r.stats.accepted += accd
        r.stats.dup_dropped += dupd
        r.stats.ooo_nak += oood
        r.stats.credit_dropped += cdropd
        r.stats.ecn_marked_rx += ecnd

        # sender: PSN space, retransmit slots, flow control, holdoffs
        s.qp.tables.npsn[sq] = (fl.base + int(nextv[i])) & MASK
        slots = {}
        for row in np.nonzero(held[i])[0]:
            psn = fl.base + int(row)
            slots[psn] = _Slot(psn, fl.plan[row].clone(),
                               int(dl[i, row]), int(retr[i, row]))
        if slots or fl.had_slot_key or int(nextv[i]) > int(next0[i]):
            s.retx.slots[sq] = slots
        s.fc.budget[sq] = int(g("f_budget")[i])
        s.fc.outstanding[sq] = int(g("f_out")[i])
        for _ in range(int(cur[i])):
            s.fc.pending[sq].popleft()
        s.fc.total_passed += int(g("f_tpassed_d")[i])
        if g("f_last_nak_w")[i]:
            s._last_nak_resend[sq] = int(g("f_last_nak")[i])
        if g("f_last_gap_w")[i]:
            s._last_gap_resend[sq] = int(g("f_last_gap")[i])
        if g("f_last_cnp_w")[i]:
            r._last_cnp_sent[rq] = int(g("f_last_cnp")[i])

    # ---- RX table scatter (one device write per receiving node) -------
    by_node: Dict[int, List[_Flow]] = {}
    for fl in flows:
        by_node.setdefault(fl.rcv.node_id, []).append(fl)
    for nid, fls in by_node.items():
        nd = nodes[nid]
        rows = jnp.asarray([fl.rq for fl in fls], jnp.int32)
        updates = {}
        for blob_name, field in zip(_RX_NAMES, _STATE_FIELDS):
            vals = jnp.asarray([int(rxf[blob_name][fl.idx]) for fl in fls],
                               jnp.int32)
            updates[field] = getattr(nd.rx_tables, field).at[rows].set(vals)
        nd.rx_tables = nd.rx_tables._replace(**updates)

    # ---- node-level stat deltas ---------------------------------------
    for n, nd in enumerate(nodes):
        nd.stats.tx_pkts += int(g("n_tx")[n])
        nd.stats.rx_pkts += int(g("n_rx")[n])
        nd.stats.retransmissions += int(g("n_retx")[n])
        nd.stats.sacked += int(g("n_sacked")[n])
        nd.stats.cnp_tx += int(g("n_cnptx")[n])
        nd.stats.cnp_rx += int(g("n_cnprx")[n])
        nd.retx.retransmissions += int(g("n_retx")[n])

    # ---- fabric / link state ------------------------------------------
    net = world.net
    now = g("now")
    wv = {n_: g(n_) for n_ in ("w_valid", "w_arr", "w_seq", "w_dst",
                               "w_flow", "w_pidx", "w_kind", "w_ap",
                               "w_sack")}

    def _wire_entries():
        for si in range(skey.WCAP):
            if not wv["w_valid"][si]:
                continue
            pkt = _rebuild_pkt(flows[int(wv["w_flow"][si])],
                               int(wv["w_kind"][si]),
                               int(wv["w_pidx"][si]),
                               int(wv["w_ap"][si]),
                               int(wv["w_sack"][si]))
            yield (int(wv["w_arr"][si]), int(wv["w_seq"][si]),
                   int(wv["w_dst"][si]), pkt)

    if star:
        net.now = now
        net._seq = g("seq")
        net.injected += g("injected_d")
        net._ctick, net._csend, net._cpop = now, g("csend"), g("cpop")
        for p in range(skey.P):
            st = net.port_stats[p]
            st.enqueued += int(g("pt_enq")[p])
            st.delivered += int(g("pt_del")[p])
            st.tail_dropped += int(g("pt_tdrop")[p])
            st.wire_dropped += int(g("pt_wdrop")[p])
            st.ecn_marked += int(g("pt_ecn")[p])
            st.max_depth = int(g("pt_maxd")[p])
        wire = [(a, s_, d, p) for a, s_, d, p in _wire_entries()]
        heapq.heapify(wire)
        net._wire = wire
        rl, rh = g("r_len"), g("r_head")
        rf, rp_ = g("r_flow"), g("r_pidx")
        rk, ra, rs = g("r_kind"), g("r_ap"), g("r_sack")
        for p in range(skey.P):
            q = collections.deque()
            for j in range(int(rl[p])):
                slot = (int(rh[p]) + j) % skey.RCAP
                q.append((_rebuild_pkt(flows[int(rf[p, slot])],
                                       int(rk[p, slot]), int(rp_[p, slot]),
                                       int(ra[p, slot]),
                                       int(rs[p, slot])), None))
            net.egress[p]._q = q
    else:
        net.now = now
        heaps: List[List] = [[] for _ in world.link_keys]
        for arr, seqv, li, pkt in _wire_entries():
            heaps[li].append((arr, seqv, pkt))
        for li, key in enumerate(world.link_keys):
            lk = net.links[key]
            heapq.heapify(heaps[li])
            lk._heap = heaps[li]
            lk._seq = int(g("l_seq")[li])
            lk.sent += int(g("l_sent_d")[li])
            lk.dropped += int(g("l_drop_d")[li])
            lk._ctick, lk._cidx = now, int(g("l_cidx")[li])


def run_fused_epoch(nodes, max_ticks: int = 100_000, idle_done: int = 8,
                    watermarks: Optional[Dict[Tuple[int, int], int]] = None
                    ) -> Optional[Dict[str, int]]:
    """Pack, run one fused epoch on device, unpack.

    Returns None when the world is not fusable or the in-graph twin hit
    a case it does not model (retry exhaustion, rkey protection error,
    wire-capacity overflow) — in that case the Python objects are
    untouched and the caller falls back to per-tick stepping.

    On success the Python world has advanced exactly as ``for _ in
    range(steps): rdma.step_network(nodes)`` would have, and the return
    dict carries ``steps``, ``wm_hit``, ``idle_exit`` and ``ticks`` (the
    ``rdma.run_network`` return-value convention).
    """
    world = try_pack(nodes, max_ticks, idle_done, watermarks)
    if world is None:
        return None
    fn = make_epoch_fn(world.skey)
    out = np.asarray(fn(jnp.asarray(world.vec0)))
    lay = world.layout
    if lay.get(out, "abort"):
        return None
    steps = lay.get(out, "steps")
    idle_exit = lay.get(out, "idle") >= idle_done
    _apply(world, out, nodes)
    return {"steps": steps, "wm_hit": bool(lay.get(out, "wm_hit")),
            "idle_exit": idle_exit,
            "ticks": (steps - 1) if idle_exit else max_ticks}
