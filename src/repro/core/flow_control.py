"""ACK-clocked flow control (paper §4.4), RX crediting (§4.3), and the
DCQCN reaction point (the congestion-control plane the paper's "open
design space" pitch points at).

Flow control sits on the *control path*: an outgoing request either
passes to the packet pipeline or is queued, bounded by a per-QP budget of
outstanding packets.  The budget is decreased by passing requests and
increased by incoming ACKs — "ACK-clocked", compatible with commodity
NICs, and the hook point for DCQCN/TIMELY-style congestion control.
That hook is now filled: with ``congestion_control="dcqcn"`` a
``DcqcnRateController`` paces the pending-queue drain through a per-QP
token bucket whose fill rate follows the DCQCN RP state machine —
multiplicative decrease on CNP arrival, timer-driven fast recovery /
additive increase between CNPs (Zhu et al., SIGCOMM'15).  The ACK clock
still bounds *inflight* packets; the rate controller bounds *departure
rate*, which is what keeps shallow switch queues below their ECN
thresholds instead of oscillating off drop-tail losses.

Crediting guards the *receive* side: the host-facing datapath advertises
consumption capacity; packets arriving with no credit available are
dropped (never stalling the pipeline) and recovered by the remote peer's
retransmission.

Invariants (property-tested in tests/test_transport.py):
  * outstanding(qp) <= window(qp) at every point in time
  * a request is never dropped by flow control, only delayed
  * credits never go negative; total accepted <= total credits granted

FPGA -> TPU design dual: on the FPGA these ledgers are small counters
next to the pipeline, updated at line rate; here they are host-side
control-plane state (python, per-QP lists) because they gate *when*
work enters the jitted data plane rather than sitting on it — the
credit check itself is replicated inside the jitted RX engines
(``pipeline._rx_decide``), which consume a credit column and return it
via the host ledger when the DMA completes.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class DcqcnConfig:
    """DCQCN reaction-point parameters, in simulator units (packets per
    tick / ticks).  Defaults are scaled for the switched-fabric testbed
    (port bandwidth ~4 pkts/tick, RTT ~6-10 ticks)."""
    line_rate: float = 4.0           # max rate per QP (pkts/tick)
    min_rate: float = 0.05           # rate floor (pkts/tick)
    g: float = 1.0 / 16.0            # EWMA gain of the alpha estimator
    rate_ai: float = 0.2             # additive increase per timer event
    alpha_timer: int = 32            # ticks w/o CNP before alpha decays
    rate_timer: int = 16             # ticks between rate-increase events
    fast_recovery: int = 3           # half-the-gap stages before AI
    # Starting rate of a fresh QP.  Spec DCQCN starts at line rate and
    # relies on PFC to make the first-RTT incast burst lossless; this
    # fabric models no PFC, so flows may start below line rate and let
    # fast recovery / AI climb instead of blasting into a shallow queue
    # blind.  ``None`` = line_rate (spec-faithful).
    initial_rate: Optional[float] = None


@dataclasses.dataclass
class FlowControlConfig:
    window: int = 64                 # max outstanding packets per QP
    congestion_control: str = "ack_clocked"   # | "static" | "dcqcn"
    dcqcn: DcqcnConfig = dataclasses.field(default_factory=DcqcnConfig)


class DcqcnRateController:
    """Per-QP DCQCN RP state machine + token-bucket pacer.

    State per QP (lazily activated on first request so idle QPs cost
    nothing on the tick path): current rate Rc, target rate Rt, the
    congestion estimate alpha, the increase-stage counter, and the token
    bucket the flow-control drain spends from.

    Rate dynamics (Zhu et al., SIGCOMM'15, timer-driven variant):
      * CNP arrival:  Rt <- Rc;  Rc <- max(Rmin, Rc * (1 - alpha/2));
                      alpha <- (1-g)*alpha + g;  stage <- 0
      * every ``rate_timer`` ticks without a cut:
          stage < fast_recovery:  Rc <- (Rc + Rt) / 2       (fast recovery)
          else:                   Rt <- min(line, Rt + Rai);
                                  Rc <- (Rc + Rt) / 2       (additive inc.)
      * every ``alpha_timer`` ticks without a CNP:
          alpha <- (1-g) * alpha

    Invariants (property-tested in tests/test_congestion.py):
      min_rate <= rate(qp) <= line_rate at every point in time.
    """

    def __init__(self, n_qps: int, cfg: DcqcnConfig = DcqcnConfig(), *,
                 burst: float = 8.0):
        self.cfg = cfg
        self.n_qps = n_qps
        self.burst = max(burst, 1.0)
        r0 = cfg.line_rate if cfg.initial_rate is None else \
            min(cfg.line_rate, max(cfg.min_rate, cfg.initial_rate))
        self.rate = [r0] * n_qps
        self.target = [r0] * n_qps
        self.alpha = [1.0] * n_qps
        self.stage = [0] * n_qps
        # buckets start near-empty: send-time bursts are budgeted by the
        # ACK window, not by a pre-filled bucket, so pacing engages from
        # the very first request instead of after one bucket's worth
        self.tokens = [1.0] * n_qps
        self._last_cut = [0] * n_qps         # last CNP / alpha-update tick
        self._last_inc = [0] * n_qps         # last rate-increase tick
        self._last_tick_now = -1
        self._active: set = set()
        # multipath (per-spine) extension: populated by enable_multipath
        self.n_paths = 1
        self.path_rate: Optional[List[List[float]]] = None   # [qpn][path]
        self.path_target: Optional[List[List[float]]] = None
        self.path_alpha: Optional[List[List[float]]] = None
        self.path_stage: Optional[List[List[int]]] = None
        self.path_tokens: Optional[List[List[float]]] = None
        self._path_last_cut: Optional[List[List[int]]] = None
        self._path_last_inc: Optional[List[List[int]]] = None
        # telemetry
        self.cnps_handled = 0
        self.rate_cuts = 0
        self.rate_increases = 0
        self.path_rate_cuts = 0

    def enable_multipath(self, n_paths: int):
        """Split each QP's reaction point into ``n_paths`` independent
        DCQCN instances — one per spine plane of a Clos fabric.  A CNP
        carrying a ``path_id`` then cuts only that plane's rate; the
        QP's aggregate rate (what the flow-control drain paces against)
        is the sum of its per-path rates.  The per-path line rate /
        floor / AI step are the QP-level parameters divided evenly, so
        the aggregate dynamics stay inside the single-path envelope."""
        if n_paths <= 1:
            return
        self.n_paths = n_paths
        r0 = [[r / n_paths] * n_paths for r in self.rate]
        self.path_rate = [row[:] for row in r0]
        self.path_target = [row[:] for row in r0]
        self.path_alpha = [[1.0] * n_paths for _ in range(self.n_qps)]
        self.path_stage = [[0] * n_paths for _ in range(self.n_qps)]
        self.path_tokens = [[0.0] * n_paths for _ in range(self.n_qps)]
        self._path_last_cut = [[0] * n_paths for _ in range(self.n_qps)]
        self._path_last_inc = [[0] * n_paths for _ in range(self.n_qps)]

    @property
    def multipath(self) -> bool:
        return self.path_rate is not None

    def activate(self, qpn: int, now: int = 0):
        if qpn not in self._active:
            self._active.add(qpn)
            self._last_cut[qpn] = now
            self._last_inc[qpn] = now
            if self.multipath:
                self._path_last_cut[qpn] = [now] * self.n_paths
                self._path_last_inc[qpn] = [now] * self.n_paths

    def on_cnp(self, qpn: int, now: int, path: int = -1):
        """Multiplicative decrease at the reaction point.  Called from
        the CNP control path — never from the ACK path, so a CNP cannot
        release ACK-clocked budget (CNPs don't ACK data).

        With multipath enabled and a valid ``path`` (the spine the
        CE-marked packet crossed, echoed in the CNP), only that path's
        rate is cut; the others keep sending — the congestion is *on
        that plane*, not on the flow."""
        self.activate(qpn, now)
        c = self.cfg
        if self.multipath and 0 <= path < self.n_paths:
            pr, pt = self.path_rate[qpn], self.path_target[qpn]
            pa = self.path_alpha[qpn]
            floor = c.min_rate / self.n_paths
            pt[path] = pr[path]
            pr[path] = max(floor, pr[path] * (1.0 - pa[path] / 2.0))
            pa[path] = min(1.0, (1.0 - c.g) * pa[path] + c.g)
            self.path_stage[qpn][path] = 0
            self._path_last_cut[qpn][path] = now
            self._path_last_inc[qpn][path] = now
            self.rate[qpn] = max(c.min_rate, sum(pr))
            self.cnps_handled += 1
            self.rate_cuts += 1
            self.path_rate_cuts += 1
            return
        self.target[qpn] = self.rate[qpn]
        self.rate[qpn] = max(c.min_rate,
                             self.rate[qpn] * (1.0 - self.alpha[qpn] / 2.0))
        self.alpha[qpn] = min(1.0, (1.0 - c.g) * self.alpha[qpn] + c.g)
        self.stage[qpn] = 0
        self._last_cut[qpn] = now
        self._last_inc[qpn] = now
        self.cnps_handled += 1
        self.rate_cuts += 1

    def tick(self, now: int):
        """Advance timers and accrue send tokens for active QPs.
        Idempotent per tick, so pacing consumers (staged retransmits,
        flow-control drain) may each poke it safely."""
        if now == self._last_tick_now:
            return
        self._last_tick_now = now
        c = self.cfg
        for qpn in sorted(self._active):
            if self.multipath:
                self._tick_multipath(qpn, now)
                continue
            if now - self._last_cut[qpn] >= c.alpha_timer:
                self.alpha[qpn] = (1.0 - c.g) * self.alpha[qpn]
                self._last_cut[qpn] = now
            if now - self._last_inc[qpn] >= c.rate_timer:
                self._last_inc[qpn] = now
                if self.stage[qpn] >= c.fast_recovery:
                    self.target[qpn] = min(c.line_rate,
                                           self.target[qpn] + c.rate_ai)
                self.rate[qpn] = min(c.line_rate,
                                     (self.rate[qpn] + self.target[qpn]) / 2)
                self.stage[qpn] += 1
                self.rate_increases += 1
            self.tokens[qpn] = min(self.burst,
                                   self.tokens[qpn] + self.rate[qpn])

    def _tick_multipath(self, qpn: int, now: int):
        """Per-path timers (same RP state machine, per-path constants =
        QP constants / n_paths), then aggregate: the QP-level rate and
        token bucket the drain consults are the sums over paths."""
        c = self.cfg
        n = self.n_paths
        line, ai = c.line_rate / n, c.rate_ai / n
        pburst = self.burst / n
        pr, pt = self.path_rate[qpn], self.path_target[qpn]
        pa, ps = self.path_alpha[qpn], self.path_stage[qpn]
        ptok = self.path_tokens[qpn]
        for path in range(n):
            if now - self._path_last_cut[qpn][path] >= c.alpha_timer:
                pa[path] = (1.0 - c.g) * pa[path]
                self._path_last_cut[qpn][path] = now
            if now - self._path_last_inc[qpn][path] >= c.rate_timer:
                self._path_last_inc[qpn][path] = now
                if ps[path] >= c.fast_recovery:
                    pt[path] = min(line, pt[path] + ai)
                pr[path] = min(line, (pr[path] + pt[path]) / 2)
                ps[path] += 1
                self.rate_increases += 1
            ptok[path] = min(pburst, ptok[path] + pr[path])
        self.rate[qpn] = max(c.min_rate, sum(pr))
        self.tokens[qpn] = min(self.burst, sum(ptok))

    def pick_path(self, qpn: int, paths: Tuple[int, ...]) -> int:
        """Congestion-aware spray: send the next packet down the live
        path with the most accumulated per-path tokens (ties -> lowest
        index), charging it one packet.  A path whose rate DCQCN cut
        accrues tokens slower, so the spray naturally shifts load off
        the congested spine.  Deficits are allowed (the QP-level bucket
        has already admitted the burst)."""
        if not self.multipath:
            return paths[0]
        ptok = self.path_tokens[qpn]
        best = max(paths, key=lambda p: (ptok[p], -p))
        ptok[best] -= 1.0
        return best

    def take(self, qpn: int, n_pkts: int) -> bool:
        """Spend ``n_pkts`` tokens if available (the pacing gate)."""
        if self.tokens[qpn] >= n_pkts:
            self.tokens[qpn] -= n_pkts
            return True
        return False

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"cnps_handled": self.cnps_handled,
                "rate_cuts": self.rate_cuts,
                "rate_increases": self.rate_increases,
                "path_rate_cuts": self.path_rate_cuts,
                "active_qps": len(self._active),
                "n_paths": self.n_paths}


class AckClockedFlowControl:
    """Per-QP outstanding-packet ledger with a pending queue.  With
    ``congestion_control="dcqcn"`` the drain is additionally gated by the
    rate controller's token bucket (rate-paced instead of burst-at-
    window)."""

    def __init__(self, n_qps: int, cfg: FlowControlConfig = FlowControlConfig()):
        self.cfg = cfg
        self.budget = [cfg.window] * n_qps
        self.pending: List[Deque] = [collections.deque() for _ in range(n_qps)]
        self.outstanding = [0] * n_qps
        self.rate: Optional[DcqcnRateController] = None
        if cfg.congestion_control == "dcqcn":
            # the bucket must admit the largest request the window can
            # pass, or pacing would deadlock the head of the queue
            self.rate = DcqcnRateController(n_qps, cfg.dcqcn,
                                            burst=float(cfg.window))
        # telemetry
        self.total_passed = 0
        self.total_queued = 0

    def request(self, qpn: int, n_pkts: int, payload=None) -> List:
        """Submit a request of ``n_pkts`` packets.  Returns the list of
        requests (the given one and/or previously queued ones) that pass
        to the packet pipeline now."""
        if self.rate is not None:
            self.rate.activate(qpn)
        self.pending[qpn].append((n_pkts, payload))
        self.total_queued += 1
        return self._drain(qpn)

    def ack(self, qpn: int, n_pkts: int = 1) -> List:
        """An ACK returns budget; queued requests may now pass."""
        self.outstanding[qpn] = max(0, self.outstanding[qpn] - n_pkts)
        self.budget[qpn] = min(self.cfg.window,
                               self.budget[qpn] + n_pkts)
        return self._drain(qpn)

    def on_cnp(self, qpn: int, now: int, path: int = -1):
        """Congestion notification: cut the QP's rate.  Deliberately does
        NOT touch budget/outstanding — a CNP never ACKs data.  ``path``
        (if >= 0 and multipath is enabled) attributes the cut to one
        spine plane only."""
        if self.rate is not None:
            self.rate.on_cnp(qpn, now, path=path)

    def tick_rate(self, now: int):
        """Advance the rate controller (timers + token accrual) without
        draining.  Lets the node spend tokens on staged retransmissions
        before the pending queue competes for them."""
        if self.rate is not None:
            self.rate.tick(now)

    def tick(self, now: int) -> List[Tuple[int, Tuple]]:
        """Rate-paced drain: accrue tokens, then release whatever the
        refreshed buckets admit.  Returns ``(qpn, (n_pkts, payload))``
        pairs.  No-op (empty) under plain ACK clocking."""
        if self.rate is None:
            return []
        self.rate.tick(now)
        released = []
        for qpn in sorted(self.rate._active):
            if self.pending[qpn]:
                released.extend((qpn, item) for item in self._drain(qpn))
        return released

    def _drain(self, qpn: int) -> List:
        passed = []
        q = self.pending[qpn]
        while q and q[0][0] <= self.budget[qpn]:
            if self.rate is not None and not self.rate.take(qpn, q[0][0]):
                break                      # paced: wait for tokens
            n_pkts, payload = q.popleft()
            self.budget[qpn] -= n_pkts
            self.outstanding[qpn] += n_pkts
            self.total_passed += 1
            passed.append((n_pkts, payload))
        return passed

    def queue_depth(self, qpn: int) -> int:
        return len(self.pending[qpn])

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        snap = {"total_passed": self.total_passed,
                "total_queued": self.total_queued,
                "outstanding": sum(self.outstanding),
                "pending": sum(len(q) for q in self.pending)}
        if self.rate is not None:
            snap["rate"] = self.rate.snapshot()
        return snap


@dataclasses.dataclass(frozen=True)
class CreditLedger:
    """Read-only per-QP view of the credit ledger — the backpressure
    signal a striped consumer (one stripe = one QP in
    ``repro.core.ingest``) reads to see where the receive side is
    gating the stream."""
    qpn: int
    credits: int             # currently available
    max_credits: int
    accepted: int            # payloads this QP's credits admitted
    dropped: int             # payloads dropped for want of a credit

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return dataclasses.asdict(self)


class CreditManager:
    """RX-side crediting: the host-facing datapath grants consumption
    capacity; a packet consuming a credit that is not there is dropped
    (paper §4.3 — rely on remote retransmission, never stall).

    Accounting is kept per QP (``ledger``) as well as in the aggregate
    counters, so stripe-per-QP consumers can attribute backpressure to
    individual stripes."""

    def __init__(self, n_qps: int, initial_credits: int = 64,
                 max_credits: int = 64):
        self.credits = [initial_credits] * n_qps
        self.max_credits = max_credits
        self.dropped_no_credit = 0
        self.accepted = 0
        self.granted = n_qps * initial_credits
        self.accepted_per_qp = [0] * n_qps
        self.dropped_per_qp = [0] * n_qps

    def note_accepted(self, qpn: int, n: int = 1):
        """Record ``n`` payloads admitted on ``qpn`` (called by the RX
        path when the in-graph credit gate accepted the packet)."""
        self.accepted += n
        self.accepted_per_qp[qpn] += n

    def note_dropped(self, qpn: int, n: int = 1):
        """Record ``n`` payloads dropped on ``qpn`` for want of credit."""
        self.dropped_no_credit += n
        self.dropped_per_qp[qpn] += n

    def ledger(self, qpn: int) -> CreditLedger:
        return CreditLedger(qpn=qpn, credits=self.credits[qpn],
                            max_credits=self.max_credits,
                            accepted=self.accepted_per_qp[qpn],
                            dropped=self.dropped_per_qp[qpn])

    def try_consume(self, qpn: int, n: int = 1) -> bool:
        if self.credits[qpn] >= n:
            self.credits[qpn] -= n
            self.note_accepted(qpn, n)
            return True
        self.note_dropped(qpn, n)
        return False

    def replenish(self, qpn: int, n: int = 1):
        """Called when the host-facing DMA engine consumes a payload."""
        add = min(n, self.max_credits - self.credits[qpn])
        self.credits[qpn] += add
        self.granted += add

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"accepted": self.accepted,
                "dropped_no_credit": self.dropped_no_credit,
                "granted": self.granted,
                "available": sum(self.credits),
                "max_credits": self.max_credits}
