"""ACK-clocked flow control (paper §4.4) and RX crediting (§4.3).

Flow control sits on the *control path*: an outgoing request either
passes to the packet pipeline or is queued, bounded by a per-QP budget of
outstanding packets.  The budget is decreased by passing requests and
increased by incoming ACKs — "ACK-clocked", compatible with commodity
NICs, and the hook point for DCQCN/TIMELY-style congestion control.

Crediting guards the *receive* side: the host-facing datapath advertises
consumption capacity; packets arriving with no credit available are
dropped (never stalling the pipeline) and recovered by the remote peer's
retransmission.

Invariants (property-tested in tests/test_transport.py):
  * outstanding(qp) <= window(qp) at every point in time
  * a request is never dropped by flow control, only delayed
  * credits never go negative; total accepted <= total credits granted

FPGA -> TPU design dual: on the FPGA these ledgers are small counters
next to the pipeline, updated at line rate; here they are host-side
control-plane state (python, per-QP lists) because they gate *when*
work enters the jitted data plane rather than sitting on it — the
credit check itself is replicated inside the jitted RX engines
(``pipeline._rx_decide``), which consume a credit column and return it
via the host ledger when the DMA completes.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class FlowControlConfig:
    window: int = 64                 # max outstanding packets per QP
    congestion_control: str = "ack_clocked"   # | "static"


class AckClockedFlowControl:
    """Per-QP outstanding-packet ledger with a pending queue."""

    def __init__(self, n_qps: int, cfg: FlowControlConfig = FlowControlConfig()):
        self.cfg = cfg
        self.budget = [cfg.window] * n_qps
        self.pending: List[Deque] = [collections.deque() for _ in range(n_qps)]
        self.outstanding = [0] * n_qps
        # telemetry
        self.total_passed = 0
        self.total_queued = 0

    def request(self, qpn: int, n_pkts: int, payload=None) -> List:
        """Submit a request of ``n_pkts`` packets.  Returns the list of
        requests (the given one and/or previously queued ones) that pass
        to the packet pipeline now."""
        self.pending[qpn].append((n_pkts, payload))
        self.total_queued += 1
        return self._drain(qpn)

    def ack(self, qpn: int, n_pkts: int = 1) -> List:
        """An ACK returns budget; queued requests may now pass."""
        self.outstanding[qpn] = max(0, self.outstanding[qpn] - n_pkts)
        self.budget[qpn] = min(self.cfg.window,
                               self.budget[qpn] + n_pkts)
        return self._drain(qpn)

    def _drain(self, qpn: int) -> List:
        passed = []
        q = self.pending[qpn]
        while q and q[0][0] <= self.budget[qpn]:
            n_pkts, payload = q.popleft()
            self.budget[qpn] -= n_pkts
            self.outstanding[qpn] += n_pkts
            self.total_passed += 1
            passed.append((n_pkts, payload))
        return passed

    def queue_depth(self, qpn: int) -> int:
        return len(self.pending[qpn])


class CreditManager:
    """RX-side crediting: the host-facing datapath grants consumption
    capacity; a packet consuming a credit that is not there is dropped
    (paper §4.3 — rely on remote retransmission, never stall)."""

    def __init__(self, n_qps: int, initial_credits: int = 64,
                 max_credits: int = 64):
        self.credits = [initial_credits] * n_qps
        self.max_credits = max_credits
        self.dropped_no_credit = 0
        self.accepted = 0
        self.granted = n_qps * initial_credits

    def try_consume(self, qpn: int, n: int = 1) -> bool:
        if self.credits[qpn] >= n:
            self.credits[qpn] -= n
            self.accepted += n
            return True
        self.dropped_no_credit += n
        return False

    def replenish(self, qpn: int, n: int = 1):
        """Called when the host-facing DMA engine consumes a payload."""
        add = min(n, self.max_credits - self.credits[qpn])
        self.credits[qpn] += add
        self.granted += add
