"""Queue-pair state (paper §4.1).

Three tables, exactly as in the packet-processing pipeline of Fig. 2:

  * connection table — remote IP / UDP port / remote QPN (static per QP)
  * state table     — expected PSN (ePSN, RX) and next PSN (nPSN, TX),
                      last-acked PSN, retransmit timer deadline
  * MSN table       — message sequence number + remaining bytes of the
                      in-flight multi-packet message (fine-grained
                      sequence control for large buffer transmissions)

Tables default to 500 QPs (paper: "per default, these tables support up
to 500 QPs, but can be configured").

FPGA -> TPU design dual: on the FPGA these tables live in BRAM and are
read/written by the pipeline in flight, one packet per cycle; here they
are arrays-of-fields so the jax engines update them functionally — the
scan oracle one packet at a time, the batched engine one *wave* (one
packet per QP) at a time, gathered/scattered by QP index.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

DEFAULT_NUM_QPS = 500


@dataclasses.dataclass
class QPTables:
    """Array-of-fields per-QP state.  All arrays shape (n_qps,)."""
    # connection table
    remote_ip: np.ndarray
    remote_port: np.ndarray
    remote_qpn: np.ndarray
    local_key: np.ndarray          # AES key id for the crypto service
    active: np.ndarray
    # state table
    epsn: np.ndarray               # next expected PSN (RX)
    npsn: np.ndarray               # next PSN to assign (TX)
    last_acked: np.ndarray         # cumulative acked PSN (TX)
    # MSN table
    msn: np.ndarray
    bytes_left: np.ndarray         # remaining bytes of in-flight message
    cur_vaddr: np.ndarray          # write cursor of in-flight message

    @staticmethod
    def create(n_qps: int = DEFAULT_NUM_QPS) -> "QPTables":
        z = lambda dt=np.int64: np.zeros(n_qps, dt)
        return QPTables(
            remote_ip=z(), remote_port=z(), remote_qpn=z(), local_key=z(),
            active=z(np.int32), epsn=z(np.int32), npsn=z(np.int32),
            last_acked=np.full(n_qps, -1, np.int64),
            msn=z(np.int32), bytes_left=z(), cur_vaddr=z(),
        )

    @property
    def n_qps(self) -> int:
        return self.epsn.shape[0]

    def as_dict(self) -> Dict[str, np.ndarray]:
        return dataclasses.asdict(self)


class QPManager:
    """Host-side QP lifecycle: setup via out-of-band exchange (the paper
    exchanges QP info over TCP sockets before the RDMA flow starts),
    teardown, and re-establishment after peer failure."""

    def __init__(self, n_qps: int = DEFAULT_NUM_QPS, node_id: int = 0):
        self.tables = QPTables.create(n_qps)
        self.node_id = node_id
        self._next_qpn = 1          # QPN 0 reserved
        self.buffers: Dict[int, np.ndarray] = {}    # rkey -> registered mem
        self._next_rkey = 1

    # ---- memory registration (initRDMA returns a remote-visible buffer)
    def register_buffer(self, size: int) -> Tuple[int, np.ndarray]:
        rkey = self._next_rkey
        self._next_rkey += 1
        buf = np.zeros(size, np.uint8)
        self.buffers[rkey] = buf
        return rkey, buf

    # ---- out-of-band QP exchange -------------------------------------
    def create_qp(self, remote_ip: int, remote_port: int,
                  start_psn: int = 0) -> int:
        qpn = self._next_qpn
        self._next_qpn += 1
        if qpn >= self.tables.n_qps:
            raise RuntimeError("QP table exhausted")
        t = self.tables
        t.remote_ip[qpn] = remote_ip
        t.remote_port[qpn] = remote_port
        t.active[qpn] = 1
        t.epsn[qpn] = start_psn
        t.npsn[qpn] = start_psn
        t.last_acked[qpn] = start_psn - 1
        return qpn

    def connect(self, qpn: int, remote_qpn: int, key_id: int = 0):
        self.tables.remote_qpn[qpn] = remote_qpn
        self.tables.local_key[qpn] = key_id

    def destroy_qp(self, qpn: int):
        t = self.tables
        t.active[qpn] = 0
        t.epsn[qpn] = t.npsn[qpn] = 0
        t.msn[qpn] = 0
        t.bytes_left[qpn] = 0

    def reestablish(self, qpn: int, start_psn: int = 0):
        """QP recovery after peer failure (framework-level fault
        tolerance reuses this together with checkpoint restore)."""
        t = self.tables
        t.active[qpn] = 1
        t.epsn[qpn] = start_psn
        t.npsn[qpn] = start_psn
        t.last_acked[qpn] = start_psn - 1
        t.msn[qpn] = 0
        t.bytes_left[qpn] = 0
