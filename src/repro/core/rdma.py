"""RDMA endpoint: the full BALBOA node (paper Fig. 1 & 3 wired together).

One ``RdmaNode`` owns the QP manager, the jax RX/TX pipelines, ACK-clocked
flow control (optionally DCQCN rate-paced: the node plays the DCQCN NP
role — in-graph CE detection, coalesced CNP emission — and RP role —
CNP-driven rate cuts pacing both fresh traffic and staged go-back-N
resends), the retransmission buffer, RX crediting and the service
chain.  Nodes exchange packets over ``repro.core.netsim`` — either the
point-to-point ``Network`` or the ``SwitchedFabric`` (shared egress
queues, where incast congestion lives) — tests drive lossy links and
assert exactly-once in-order delivery; benchmarks measure
latency/throughput vs. buffer size exactly like the paper's Fig. 4.

FPGA -> TPU design dual: the FPGA node is one deep pipeline fed by the
MAC; this node is a host-side control plane (verbs, ACK clocking,
retransmit timers — BALBOA's sequencer logic) around jitted data-plane
kernels.  ``engine`` selects the RX data plane: ``"batched"`` (the
multi-QP wave engine, default — one jitted step per network tick across
all QPs) or ``"scan"`` (the per-packet oracle it is diffed against).
TX PSN assignment stays host-side here (one message at a time at the
verbs layer); the batched TX engine (``pipeline.tx_pipeline_batched``)
serves bulk command streams and is exercised by tests/benchmarks.

Programming model mirrors the Coyote-thread verbs of §4.6:
    qpn, rkey, buf = node.init_rdma(max_size, remote_node)
    node.rdma_write(qpn, data)           # REMOTE_RDMA_WRITE
    node.rdma_read(qpn, length)          # REMOTE_RDMA_READ
    node.check_completed(qpn)            # completion polling
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import packet as pk
from repro.core import pipeline as pipe
from repro.core.flow_control import (AckClockedFlowControl, CreditManager,
                                     DcqcnConfig, FlowControlConfig)
from repro.core.qp import QPManager
from repro.core.retransmit import RetransmissionBuffer
from repro.core.services import ServiceChain

RX_PAD = 16           # pad RX batches to multiples of this (jit stability)


@dataclasses.dataclass
class NodeStats:
    tx_pkts: int = 0
    rx_pkts: int = 0
    accepted: int = 0
    dup_dropped: int = 0
    ooo_nak: int = 0
    credit_dropped: int = 0
    retransmissions: int = 0
    dpi_flagged: int = 0
    ecn_marked_rx: int = 0       # CE-marked payload packets seen (NP)
    cnp_tx: int = 0              # CNPs emitted (NP, after coalescing)
    cnp_rx: int = 0              # CNPs received (RP)
    prot_errors: int = 0         # rkey mismatches NAKed at this responder
    nak_prot_rx: int = 0         # protection NAKs received (requester side)
    sacked: int = 0              # slots released by selective ACK bitmaps

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return dataclasses.asdict(self)


# jitted-engine counter column -> the host-side NodeStats counter it
# mirrors (the reconciliation tests assert per-column sums match)
ENGINE_COUNTERS = {
    "acc_cnt": "accepted",
    "dup_cnt": "dup_dropped",
    "ooo_cnt": "ooo_nak",
    "cdrop_cnt": "credit_dropped",
    "ecn_tot": "ecn_marked_rx",
}


CONGESTION_CONTROLS = ("ack_clocked", "static", "dcqcn")
RX_MODES = ("go_back_n", "selective_repeat")
PATH_SELECTS = (None, "ecmp", "spray")


class RdmaNode:
    def __init__(self, node_id: int, network, *,
                 n_qps: int = 500, mtu: int = pk.MTU,
                 fc_window: int = 64, rx_credits: int = 64,
                 services: Optional[ServiceChain] = None,
                 sniffer=None, engine: str = "batched",
                 congestion_control: str = "ack_clocked",
                 dcqcn: Optional[DcqcnConfig] = None,
                 rx_mode: str = "go_back_n",
                 path_select: Optional[str] = None,
                 sr_gap_lag: int = 12):
        if engine not in pipe.RX_ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"choose from {sorted(pipe.RX_ENGINES)}")
        if congestion_control not in CONGESTION_CONTROLS:
            raise ValueError(
                f"unknown congestion_control {congestion_control!r}; "
                f"choose from {CONGESTION_CONTROLS}")
        if rx_mode not in RX_MODES:
            raise ValueError(f"unknown rx_mode {rx_mode!r}; "
                             f"choose from {RX_MODES}")
        if path_select not in PATH_SELECTS:
            raise ValueError(f"unknown path_select {path_select!r}; "
                             f"choose from {PATH_SELECTS}")
        if rx_mode == "selective_repeat" and fc_window > pipe.SR_WINDOW:
            raise ValueError(
                f"fc_window={fc_window} exceeds the selective-repeat "
                f"receive window ({pipe.SR_WINDOW}): the sender could "
                f"legally burst past what the RX bitmap can hold")
        self.node_id = node_id
        self.net = network                   # Network / SwitchedFabric / Clos
        self.engine = engine
        self._rx_pipe = pipe.RX_ENGINES[engine]
        self.mtu = mtu
        self.rx_mode = rx_mode
        self._sr = rx_mode == "selective_repeat"
        self.path_select = path_select
        self.sr_gap_lag = sr_gap_lag
        self.qp = QPManager(n_qps, node_id)
        self.rx_tables = pipe.make_rx_tables(n_qps, rx_credits)
        if self._sr:
            # whole-node RX mode: both peers of a QP must agree on it
            # (a selective-repeat sender emits per-packet RETHs)
            self.rx_tables = self.rx_tables._replace(
                sr=jnp.ones_like(self.rx_tables.sr))
        self.tx_tables = pipe.make_tx_tables(n_qps)
        self.fc = AckClockedFlowControl(n_qps, FlowControlConfig(
            fc_window, congestion_control=congestion_control,
            dcqcn=dcqcn if dcqcn is not None else DcqcnConfig()))
        if (self.fc.rate is not None and path_select == "spray"
                and getattr(network, "n_paths", 1) > 1):
            # per-spine DCQCN: CNPs attribute congestion to one plane
            self.fc.rate.enable_multipath(network.n_paths)
        self.credits = CreditManager(n_qps, rx_credits, rx_credits)
        self.retx = RetransmissionBuffer(timeout_ticks=64)
        self.services = services
        self.sniffer = sniffer
        self.stats = NodeStats()
        self.recorder = None                 # telemetry.FlightRecorder
        self.qp_errors: set = set()                  # QPs dead on retry budget
        self._fatal_qps: set = set()                 # protection errors: never
                                                     # retransmit, only recover
        self._exhausted_seen = 0                     # retx.exhausted cursor
        self._completions: Dict[int, int] = {}       # qpn -> completed msgs
        self._qp_buffer: Dict[int, Tuple[int, np.ndarray]] = {}
        self._peer: Dict[int, int] = {}              # qpn -> remote node id
        # contiguous-byte completion watermark per QP: the highest byte
        # offset of the registered buffer such that every byte below it
        # has been accepted by the RX pipeline.  PSN checking accepts
        # strictly in order, so ``dma_addr + dma_len`` of the newest
        # accepted payload IS the contiguous frontier — streaming
        # consumers (``repro.core.ingest``) poll it between network
        # ticks to hand completed fragment tiles onward mid-transfer.
        self._rx_progress: Dict[int, int] = {}       # qpn -> bytes landed
        self._remote_rkey: Dict[int, int] = {}       # qpn -> peer buffer rkey
        self._local_rkey: Dict[int, int] = {}        # qpn -> our buffer rkey
        self._read_pending: Dict[int, int] = {}      # qpn -> bytes expected
        self._last_nak_resend: Dict[int, int] = {}   # qpn -> tick
        self._last_cnp_sent: Dict[int, int] = {}     # qpn -> tick (coalescing)
        # retransmissions awaiting pacing tokens (DCQCN only: the rate
        # limiter sits at the wire, so resends are paced like first
        # transmissions instead of bursting back into the hot queue)
        self._retx_staged: Dict[int, List[pk.Packet]] = {}
        # selective-repeat host state --------------------------------------
        # out-of-order byte intervals not yet contiguous with the
        # watermark: qpn -> {start byte: end byte}
        self._sr_pend: Dict[int, Dict[int, int]] = {}
        # LAST/ONLY packets accepted out of order: their message
        # completion is deferred until epsn passes them
        self._sr_pending_last: Dict[int, List[int]] = {}
        self._last_gap_resend: Dict[int, int] = {}   # qpn -> tick
        self._path_rr: Dict[int, int] = {}           # qpn -> spray cursor

    # --------------------------------------------------------- telemetry
    def attach_recorder(self, rec):
        """Record transport lifecycle events (retransmit, SACK/NAK, CNP
        tx/rx, completion, QP error) into a ``telemetry.FlightRecorder``
        — one track per (node, QP)."""
        self.recorder = rec

    def _rec(self, kind: str, qpn: int, **attrs):
        if self.recorder is not None:
            self.recorder.record(self.net.now, kind,
                                 ("qp", f"{self.node_id}:{qpn}"), **attrs)

    def engine_counters(self) -> Dict[str, np.ndarray]:
        """Harvest the per-QP counter columns carried through the jitted
        RX engine state (``pipeline.COUNTER_FIELDS``).  This is the ONE
        host sync observability costs, and it happens here — at an epoch
        boundary, when a registry snapshot asks — never inside the
        per-batch engine calls."""
        return {host: np.asarray(getattr(self.rx_tables, col))
                for col, host in ENGINE_COUNTERS.items()}

    def engine_totals(self) -> Dict[str, int]:
        return {k: int(v.sum()) for k, v in self.engine_counters().items()}

    def snapshot(self) -> dict:
        """Common telemetry shape: every stats surface of the node."""
        return {"stats": self.stats.snapshot(),
                "engine": self.engine_totals(),
                "fc": self.fc.snapshot(),
                "credits": self.credits.snapshot(),
                "retx": self.retx.snapshot(),
                "completions": sum(self._completions.values()),
                "qp_errors": len(self.qp_errors)}

    # ------------------------------------------------------------- verbs
    def init_rdma(self, max_size: int, remote: "RdmaNode",
                  key_id: int = 0) -> Tuple[int, int, np.ndarray]:
        """Out-of-band QP + buffer exchange (paper §4.6: 'completely
        hidden abstraction' over TCP sockets)."""
        rkey_l, buf_l = self.qp.register_buffer(max_size)
        rkey_r, buf_r = remote.qp.register_buffer(max_size)
        qpn_l = self.qp.create_qp(remote.node_id, pk.UDP_DPORT_ROCE)
        qpn_r = remote.qp.create_qp(self.node_id, pk.UDP_DPORT_ROCE)
        self.qp.connect(qpn_l, qpn_r, key_id)
        remote.qp.connect(qpn_r, qpn_l, key_id)
        self._qp_buffer[qpn_l] = (rkey_r, buf_l)     # local view
        remote._qp_buffer[qpn_r] = (rkey_l, buf_r)
        self._peer[qpn_l] = remote.node_id
        remote._peer[qpn_r] = self.node_id
        # out-of-band: each side knows the peer's buffer under its own QP
        self._remote_rkey[qpn_l] = rkey_r
        remote._remote_rkey[qpn_r] = rkey_l
        # ... and arms protection on its own: the RX pipeline checks every
        # RETH against the registered rkey (host path: _on_read_request)
        self._local_rkey[qpn_l] = rkey_l
        remote._local_rkey[qpn_r] = rkey_r
        self.rx_tables = self.rx_tables._replace(
            rkey=self.rx_tables.rkey.at[qpn_l].set(rkey_l))
        remote.rx_tables = remote.rx_tables._replace(
            rkey=remote.rx_tables.rkey.at[qpn_r].set(rkey_r))
        return qpn_l, rkey_r, buf_l

    def rdma_write(self, qpn: int, data: np.ndarray, remote_addr: int = 0,
                   coll: Optional[Tuple[int, int, int]] = None):
        """One-sided WRITE of ``data`` into the peer's registered buffer.
        Messages larger than the flow-control window are chunked into
        window-sized sub-messages so the ACK clock can pace them.

        ``coll = (tag, src, nsrc)`` marks every packet of the message as
        a collective CHUNK contribution for the in-fabric reduction
        offload (``repro.core.collectives``): the switch absorbs tagged
        contributions and forwards one summed stream per reduction slot.
        Transport semantics are unchanged — tagged packets still ride
        flow control, retransmission and pacing."""
        self._submit(qpn, "write", remote_addr, np.asarray(data, np.uint8),
                     coll=coll)

    def rdma_read(self, qpn: int, length: int, remote_addr: int = 0):
        """One-sided READ from the peer's buffer into ours."""
        for passed in self.fc.request(qpn, 1,
                                      ("read", remote_addr, length, None)):
            self._dispatch(qpn, passed[1])

    def check_completed(self, qpn: int) -> int:
        return self._completions.get(qpn, 0)

    def remote_qpn(self, qpn: int) -> int:
        """The peer QPN this local QP is connected to (from the
        connection table ``init_rdma`` filled in) — callers must derive
        the remote end from here, never by inspecting the peer's
        buffer dict."""
        return self._remote_qpn(qpn)

    def rx_progress(self, qpn: int) -> int:
        """Contiguous bytes landed in this QP's registered buffer since
        the last ``reset_rx_progress`` — the completion watermark a
        streaming consumer polls between ``step_network`` ticks."""
        return self._rx_progress.get(qpn, 0)

    def reset_rx_progress(self, qpn: int):
        """Re-arm the watermark before issuing a new transfer whose DMA
        addresses restart at the buffer base."""
        self._rx_progress.pop(qpn, None)
        self._sr_pend.pop(qpn, None)

    def expected_completions(self, nbytes: int) -> int:
        """How many RX completions one ``rdma_write`` of ``nbytes``
        produces at the peer (one per flow-control sub-message) —
        collective schedules poll ``check_completed`` against this."""
        return max(1, -(-max(nbytes, 1) // self._sub_message_bytes()))

    # -------------------------------------------------------- TX internals
    def _sub_message_bytes(self) -> int:
        """TX chunking policy: messages split into half-window-sized
        sub-messages so the ACK clock can pace them (always a multiple
        of the MTU, so collective fragment numbering stays aligned)."""
        return max(1, (self.fc.cfg.window // 2)) * self.mtu

    def _submit(self, qpn: int, kind: str, remote_addr: int,
                data: np.ndarray, coll=None):
        chunk_bytes = self._sub_message_bytes()
        for off in range(0, max(len(data), 1), chunk_bytes):
            chunk = data[off:off + chunk_bytes]
            n_pkts = pk.read_resp_npkts(len(chunk), self.mtu)
            # sub-messages fragment independently, so collective fragment
            # numbering continues across them (chunk_bytes % mtu == 0)
            sub = None if coll is None else (*coll, off // self.mtu)
            for passed in self.fc.request(
                    qpn, n_pkts, (kind, remote_addr + off, chunk, sub)):
                self._dispatch(qpn, passed[1])

    def _dispatch(self, qpn: int, item):
        kind, addr, payload, coll = item
        if kind == "read":
            self._emit_read_request(qpn, addr, payload)
        else:
            self._emit_message(qpn, addr, payload,
                               op="write" if kind == "write" else "read_resp",
                               coll=coll)

    def _emit_message(self, qpn: int, remote_addr: int,
                      data: np.ndarray, op: str = "write", coll=None):
        t = self.qp.tables
        start_psn = int(t.npsn[qpn])
        rkey = self._remote_rkey[qpn]
        pkts = pk.fragment_message(
            int(t.remote_qpn[qpn]), start_psn, remote_addr, rkey, data,
            op=op, mtu=self.mtu, src_ip=self.node_id,
            dst_ip=int(t.remote_ip[qpn]), coll=coll,
            addr_per_pkt=self._sr)
        t.npsn[qpn] = (start_psn + len(pkts)) & pk.PSN_MASK
        for p in pkts:
            # retransmission buffer holds every payload until remote ACK
            self.retx.hold(qpn, p, self.net.now)
            self._send(qpn, p)

    def _emit_read_request(self, qpn: int, remote_addr: int, length: int):
        t = self.qp.tables
        psn = int(t.npsn[qpn])
        p = pk.make_read_request(int(t.remote_qpn[qpn]), psn, remote_addr,
                                 self._remote_rkey[qpn], length,
                                 src_ip=self.node_id,
                                 dst_ip=int(t.remote_ip[qpn]))
        # responder will stream n_pkts of responses; budget accounted as 1
        t.npsn[qpn] = (psn + 1) & pk.PSN_MASK
        self._read_pending[qpn] = length
        self.retx.hold(qpn, p, self.net.now)
        self._send(qpn, p)

    def _send(self, local_qpn: int, p: pk.Packet):
        self.stats.tx_pkts += 1
        n_paths = getattr(self.net, "n_paths", 0)
        if self.path_select and n_paths > 1 and p.opcode in pk.PAYLOAD_OPS:
            # stamp the spine this payload should ride; control packets
            # stay unstamped (the fabric picks).  Happens AFTER the
            # retransmit buffer cloned the packet, so a resend re-picks
            # its path — re-sending down a failed or congested spine
            # would repeat the very loss being repaired.
            p.path_id = self._pick_path(local_qpn, n_paths)
        if self.sniffer is not None:
            self.sniffer.capture(p, self.net.now, direction="tx")
        dst = self._peer[local_qpn]
        self.net.send(self.node_id, dst, p)

    def _pick_path(self, qpn: int, n_paths: int) -> int:
        paths = getattr(self.net, "alive_paths", None) \
            or tuple(range(n_paths))
        if self.path_select == "ecmp":
            # stable per-flow hash: one QP stays on one spine
            h = (qpn * 0xC2B2AE3D + self.node_id * 0x9E3779B1) & 0xFFFFFFFF
            return paths[h % len(paths)]
        rate = self.fc.rate
        if rate is not None and rate.multipath:
            # congestion-aware spray: weight by per-path DCQCN tokens
            return rate.pick_path(qpn, paths)
        c = self._path_rr.get(qpn, 0)
        self._path_rr[qpn] = c + 1
        return paths[c % len(paths)]

    # -------------------------------------------------------- RX internals
    def on_packets(self, pkts: List[pk.Packet]):
        """Feed an arriving packet batch through the (jax) RX pipeline."""
        if not pkts:
            return
        self.stats.rx_pkts += len(pkts)
        if self.sniffer is not None:
            for p in pkts:
                self.sniffer.capture(p, self.net.now, direction="rx")
        # control-plane packets (ACK/NAK) handled on the control path
        data_pkts = []
        for p in pkts:
            if p.opcode == pk.ACK:
                self._on_ack(p)
            elif p.opcode == pk.NAK:
                self._on_nak(p)
            elif p.opcode == pk.NAK_PROT:
                self._on_nak_prot(p)
            elif p.opcode == pk.CNP:
                self._on_cnp(p)
            elif p.opcode == pk.READ_REQUEST:
                self._on_read_request(p)
            else:
                data_pkts.append(p)
        if not data_pkts:
            return
        batch_np = pk.batch_from_packets(data_pkts, self.mtu)
        n = len(data_pkts)
        # pad to the next power-of-two multiple of RX_PAD: bounds the
        # number of distinct jit shapes of the RX pipeline
        target = RX_PAD
        while target < n:
            target *= 2
        pad = target - n
        if pad:
            for k, v in batch_np.items():
                batch_np[k] = np.concatenate(
                    [v, np.zeros((pad,) + v.shape[1:], v.dtype)])
            batch_np["valid"][n:] = 0
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        # sync credits from the host-side credit manager
        self.rx_tables = self.rx_tables._replace(
            credits=jnp.asarray(self.credits.credits, jnp.int32))
        self.rx_tables, res = self._rx_pipe(self.rx_tables, batch)
        res = res._asdict()
        ecn_cnt = np.asarray(res.pop("ecn_cnt"))     # (Q,) per-QP CE tally
        res = {k: np.asarray(v)[:n] for k, v in res.items()}
        self.credits.credits = list(np.asarray(self.rx_tables.credits))
        # attribute CE marks to the spine that carried them, so the CNP
        # can steer the sender's per-path rate cut (ecn_cnt only says
        # *which QP*; the packet's path_id says which plane)
        ce_path: Dict[int, int] = {}
        for p in data_pkts:
            if p.ecn and p.opcode in pk.PAYLOAD_OPS:
                ce_path[p.qpn] = p.path_id
        self._emit_cnps(ecn_cnt, ce_path)

        # ---- service chain over the accepted payload stream -------------
        payload = batch_np["payload"][:n]
        plen = batch_np["plen"][:n]
        flags = np.zeros(n, np.int64)
        if self.services is not None:
            out, f = self.services.process(jnp.asarray(payload),
                                           jnp.asarray(plen))
            payload = np.asarray(out)
            flags = np.asarray(f)

        # ---- DMA accepted payloads into registered memory ----------------
        for i, p in enumerate(data_pkts):
            qpn = p.qpn
            if res["accept"][i]:
                self.stats.accepted += 1
                if flags[i]:
                    # DPI decision flag -> host-directed command (user
                    # interrupt analogue): count + still deliver
                    self.stats.dpi_flagged += 1
                buf = self._buffer_for(qpn)
                if buf is not None:
                    a = int(res["dma_addr"][i])
                    ln = int(res["dma_len"][i])
                    buf[a:a + ln] = payload[i][:ln]
                    if self._sr:
                        # out-of-order acceptance: merge the landed
                        # interval, advance the contiguous watermark
                        # only when the gap before it has filled
                        self._sr_note_progress(qpn, a, ln)
                    else:
                        # in-order acceptance makes this the contiguous
                        # frontier (max against replays of acked data)
                        self._rx_progress[qpn] = max(
                            self._rx_progress.get(qpn, 0), a + ln)
                self.credits.note_accepted(qpn)
                # host consumes the payload -> credit returns (paper §4.3)
                self._replenish_credit(qpn)
                if res["send_ack"][i]:
                    self._send_ctrl(qpn, pk.make_ack(
                        self._remote_qpn(qpn), int(res["ack_psn"][i]),
                        sack=int(res["sack"][i])))
                if p.opcode in (pk.WRITE_LAST, pk.WRITE_ONLY,
                                pk.READ_RESP_LAST, pk.READ_RESP_ONLY):
                    if self._sr:
                        # completion only once every earlier PSN landed
                        self._sr_pending_last.setdefault(
                            qpn, []).append(p.psn)
                    else:
                        self._completions[qpn] = \
                            self._completions.get(qpn, 0) + 1
                        self._rec("completion", qpn, psn=p.psn)
            elif res["dup"][i]:
                self.stats.dup_dropped += 1
                self._send_ctrl(qpn, pk.make_ack(self._remote_qpn(qpn),
                                                 int(res["ack_psn"][i]),
                                                 sack=int(res["sack"][i])))
            elif res["dropped_credit"][i]:
                self.stats.credit_dropped += 1   # silent drop: peer retransmits
                self.credits.note_dropped(qpn)
            elif res["rkey_err"][i]:
                # remote-access protection error: the wire rkey does not
                # match the registered buffer — NAK fatally, serve nothing
                self.stats.prot_errors += 1
                self._send_ctrl(qpn, pk.make_nak_prot(
                    self._remote_qpn(qpn), p.psn))
            elif res["ooo"][i]:
                self.stats.ooo_nak += 1
                self._rec("nak", qpn, psn=p.psn,
                          expected=int(res["ack_psn"][i]) + 1)
                self._send_ctrl(qpn, pk.make_ack(self._remote_qpn(qpn),
                                                 int(res["ack_psn"][i]),
                                                 nak=True))
        if self._sr and self._sr_pending_last:
            self._flush_sr_completions()

    # ---- selective-repeat host bookkeeping -----------------------------
    def _sr_note_progress(self, qpn: int, a: int, ln: int):
        """Merge the byte interval ``[a, a+ln)`` into this QP's landed
        set and advance the contiguous watermark over any now-filled
        gaps — the streaming-consumer invariant (every byte below the
        watermark is present) survives out-of-order DMA."""
        pend = self._sr_pend.setdefault(qpn, {})
        pend[a] = max(pend.get(a, 0), a + ln)
        fr = self._rx_progress.get(qpn, 0)
        advanced = True
        while advanced:
            advanced = False
            for s in sorted(pend):
                if s > fr:
                    break
                fr = max(fr, pend.pop(s))
                advanced = True
        self._rx_progress[qpn] = fr
        if not pend:
            self._sr_pend.pop(qpn, None)

    def _flush_sr_completions(self):
        """Deferred message completions: a LAST/ONLY fragment accepted
        out of order completes only when the receive window's cumulative
        edge (epsn) has passed it — i.e. every fragment before it
        landed."""
        span = pk.PSN_MASK + 1
        epsn_col = np.asarray(self.rx_tables.epsn)
        for qpn in list(self._sr_pending_last):
            epsn = int(epsn_col[qpn])
            lst = self._sr_pending_last[qpn]
            done = [ps for ps in lst
                    if ((ps - epsn) % span) > pk.PSN_MASK // 2]
            if not done:
                continue
            self._completions[qpn] = self._completions.get(qpn, 0) \
                + len(done)
            for ps in done:
                self._rec("completion", qpn, psn=ps)
            rest = [ps for ps in lst
                    if ((ps - epsn) % span) <= pk.PSN_MASK // 2]
            if rest:
                self._sr_pending_last[qpn] = rest
            else:
                del self._sr_pending_last[qpn]

    def _on_ack(self, p: pk.Packet):
        qpn = self._local_qpn(p.qpn)
        released = self.retx.ack(qpn, p.ack_psn)
        if p.sack_bits:
            sacked = self.retx.sack_release(qpn, p.ack_psn, p.sack_bits)
            self.stats.sacked += sacked
            released += sacked
            if sacked:
                self._rec("sack", qpn, released=sacked, ack_psn=p.ack_psn)
            self._maybe_gap_resend(qpn, p)
        for passed in self.fc.ack(qpn, max(released, 1)):
            self._dispatch(qpn, passed[1])

    def _maybe_gap_resend(self, qpn: int, p: pk.Packet):
        """Selective-repeat fast retransmit: the SACK bitmap proves
        delivery up to its highest bit, so held slots lagging it by
        ``sr_gap_lag``+ PSNs are gaps (lost, not just reordered) —
        resend exactly those, rate-limited like NAK bursts."""
        if qpn in self._fatal_qps:
            return
        last = self._last_gap_resend.get(qpn, -10**9)
        if self.net.now - last < self.NAK_HOLDOFF:
            return
        hi = (p.ack_psn + p.sack_bits.bit_length()) & pk.PSN_MASK
        resend = self.retx.gap_resend(qpn, p.ack_psn, hi,
                                      self.sr_gap_lag, self.net.now)
        if resend:
            self._last_gap_resend[qpn] = self.net.now
            for rp in resend:
                self._send_retx(qpn, rp)

    CNP_HOLDOFF = 8      # ticks: NP-side CNP coalescing window per QP

    def _emit_cnps(self, ecn_cnt: np.ndarray,
                   ce_path: Optional[Dict[int, int]] = None):
        """DCQCN NP role: one (coalesced) CNP per QP that saw CE marks in
        this batch.  Runs unconditionally — the notification point needs
        no local DCQCN state, so any receiver disciplines any sender.
        ``ce_path`` maps QP -> the spine a CE-marked packet crossed; the
        CNP echoes it so a multipath reaction point cuts that plane."""
        for qpn in np.nonzero(ecn_cnt)[0]:
            qpn = int(qpn)
            self.stats.ecn_marked_rx += int(ecn_cnt[qpn])
            last = self._last_cnp_sent.get(qpn, -10**9)
            if self.net.now - last < self.CNP_HOLDOFF:
                continue
            self._last_cnp_sent[qpn] = self.net.now
            self.stats.cnp_tx += 1
            self._rec("cnp_tx", qpn, marks=int(ecn_cnt[qpn]))
            path = ce_path.get(qpn, -1) if ce_path else -1
            self._send_ctrl(qpn, pk.make_cnp(self._remote_qpn(qpn),
                                             src_ip=self.node_id,
                                             path_id=path))

    def _on_cnp(self, p: pk.Packet):
        """DCQCN RP role: cut this QP's rate.  A CNP is a pure
        congestion signal — it must NOT release retransmission slots or
        ACK-clocked budget (go-back-N state is untouched)."""
        qpn = self._local_qpn(p.qpn)
        self.stats.cnp_rx += 1
        self._rec("cnp_rx", qpn, path=p.path_id)
        self.fc.on_cnp(qpn, self.net.now, path=p.path_id)

    NAK_HOLDOFF = 8      # ticks: rate-limit go-back-N resend bursts

    def _on_nak_prot(self, p: pk.Packet):
        """Remote-access protection error: fatal for the QP.  Unlike a
        sequence NAK there is nothing to retransmit — the rkey can never
        become right by retrying — so the QP goes straight to the error
        state (recover via ``reestablish_qp`` after re-exchanging keys)."""
        qpn = self._local_qpn(p.qpn)
        self.stats.nak_prot_rx += 1
        self.qp_errors.add(qpn)
        self._fatal_qps.add(qpn)

    def _on_nak(self, p: pk.Packet):
        qpn = self._local_qpn(p.qpn)
        if qpn in self._fatal_qps:
            return       # fatal QP: no more replays until re-established
        last = self._last_nak_resend.get(qpn, -10**9)
        if self.net.now - last < self.NAK_HOLDOFF:
            return       # a resend burst is already in flight
        self._last_nak_resend[qpn] = self.net.now
        expected = (p.ack_psn + 1) & pk.PSN_MASK
        for rp in self.retx.nak(qpn, expected, self.net.now):
            self._send_retx(qpn, rp)

    def _send_retx(self, qpn: int, rp: pk.Packet):
        """Send a retransmission — immediately under plain ACK clocking,
        through the pacing bucket under DCQCN (the rate limiter sits at
        the wire: a resend burst must not re-congest the very queue
        whose overflow it is repairing)."""
        if qpn in self._fatal_qps:
            return       # fatal QP: hold fire until re-established
        if self.fc.rate is None:
            self.stats.retransmissions += 1
            self._rec("retransmit", qpn, psn=rp.psn)
            self._send(qpn, rp)
            return
        staged = self._retx_staged.setdefault(qpn, [])
        if any(s.psn == rp.psn for s in staged):
            return       # this PSN is already awaiting tokens
        staged.append(rp)

    def _drain_staged_retx(self):
        rate = self.fc.rate
        if rate is None or not self._retx_staged:
            return
        for qpn in sorted(self._retx_staged):
            if qpn in self._fatal_qps:
                continue     # parked until reestablish_qp clears the stage
            q = self._retx_staged[qpn]
            while q and rate.take(qpn, 1):
                self.stats.retransmissions += 1
                self._rec("retransmit", qpn, psn=q[0].psn)
                self._send(qpn, q.pop(0))
        self._retx_staged = {q: v for q, v in self._retx_staged.items() if v}

    def _on_read_request(self, p: pk.Packet):
        """Responder side of RDMA READ: stream the requested region
        through the same flow-control path as writes (the response
        stream is ACK-clocked too).  The wire rkey is validated against
        the registered buffer first — a mismatch is NAKed with a
        protection error instead of serving the read."""
        qpn = p.qpn                      # our local QPN (dst of the request)
        if p.rkey != self._local_rkey.get(qpn):
            self.stats.prot_errors += 1
            self._send_ctrl(qpn, pk.make_nak_prot(self._remote_qpn(qpn),
                                                  p.psn))
            return
        buf = self._buffer_for(qpn)
        data = buf[p.vaddr:p.vaddr + p.dma_len] if buf is not None else \
            np.zeros(p.dma_len, np.uint8)
        # ACK the request BEFORE streaming the response: on a shaped
        # link the ACK would otherwise queue behind the whole response
        # burst, leaving the requester's READ_REQUEST retransmit slot
        # held (and its fc budget debited) for the entire stream — and
        # parking the fused epoch core (core.fused) in per-tick fallback
        # for exactly as long, since a non-payload held slot is one of
        # the things its in-graph twin does not model
        self._send_ctrl(qpn, pk.make_ack(self._remote_qpn(qpn), p.psn))
        self._submit(qpn, "read_resp", 0, data)

    # ------------------------------------------------------------ timers
    def tick(self):
        # rate-paced drain (DCQCN): token buckets refill once per tick;
        # staged retransmissions spend tokens before new requests (they
        # carry the oldest PSNs, and go-back-N wants them in order)
        self.fc.tick_rate(self.net.now)
        self._drain_staged_retx()
        for qpn, item in self.fc.tick(self.net.now):
            self._dispatch(qpn, item[1])
        for qpn, rp in self.retx.tick(self.net.now):
            self._send_retx(qpn, rp)
        # surface retry-budget exhaustion as a QP error instead of
        # retransmitting forever (upper layers re-establish or fail over)
        exhausted = self.retx.exhausted
        while self._exhausted_seen < len(exhausted):
            qpn, psn = exhausted[self._exhausted_seen]
            self._exhausted_seen += 1
            if qpn not in self.qp_errors:
                self._rec("qp_error", qpn, psn=psn)
            self.qp_errors.add(qpn)

    def qp_error(self, qpn: int) -> bool:
        """True if the QP died on retry-budget exhaustion (fatal until
        ``reestablish_qp``)."""
        return qpn in self.qp_errors

    def reestablish_qp(self, qpn: int, start_psn: int = 0):
        """Tear down the errored QP's transport state and re-establish it
        (paper §4.6 failover: fresh PSN space, empty retransmit ring,
        drained flow-control queue)."""
        self.retx.slots.pop(qpn, None)
        self._retx_staged.pop(qpn, None)     # stale PSNs must not leak
        self.fc.pending[qpn].clear()
        self.fc.outstanding[qpn] = 0
        self.fc.budget[qpn] = self.fc.cfg.window
        self._last_nak_resend.pop(qpn, None)
        self._last_cnp_sent.pop(qpn, None)
        self._last_gap_resend.pop(qpn, None)
        self._rx_progress.pop(qpn, None)
        self._sr_pend.pop(qpn, None)
        self._sr_pending_last.pop(qpn, None)
        self.qp_errors.discard(qpn)
        self._fatal_qps.discard(qpn)
        self.qp.reestablish(qpn, start_psn)
        t = self.qp.tables
        # mirror the reset into the jitted RX/TX tables
        self.rx_tables = self.rx_tables._replace(
            epsn=self.rx_tables.epsn.at[qpn].set(start_psn),
            msn=self.rx_tables.msn.at[qpn].set(0),
            bytes_left=self.rx_tables.bytes_left.at[qpn].set(0),
            cur_vaddr=self.rx_tables.cur_vaddr.at[qpn].set(0),
            rxbit=self.rx_tables.rxbit.at[qpn].set(0))
        t.npsn[qpn] = start_psn

    # ------------------------------------------------------------ helpers
    def _buffer_for(self, qpn: int):
        ent = self._qp_buffer.get(qpn)
        return ent[1] if ent else None

    def _remote_qpn(self, local_qpn: int) -> int:
        return int(self.qp.tables.remote_qpn[local_qpn])

    def _local_qpn(self, qpn_in_packet: int) -> int:
        return qpn_in_packet      # packets carry the destination QPN

    def _replenish_credit(self, qpn: int):
        self.credits.replenish(qpn, 1)

    def _send_ctrl(self, local_qpn: int, p: pk.Packet):
        self._send(local_qpn, p)


def step_network(nodes: List[RdmaNode]) -> None:
    """Advance the simulation by exactly ONE tick: deliver in-flight
    packets to their destination nodes, then run every node's timer
    tick.  The incremental unit ``run_network`` is built from — and the
    primitive streaming consumers (``repro.core.ingest``) interleave
    with completion-watermark polls to process data *as it arrives*
    instead of store-and-forwarding whole transfers."""
    net = nodes[0].net
    delivered = net.tick()
    for (src, dst), pkts in delivered.items():
        if pkts:
            nodes[dst].on_packets(pkts)
    for nd in nodes:
        nd.tick()


def network_pending(nodes: List[RdmaNode]) -> bool:
    """True while any transport work remains: packets in flight, unacked
    payloads awaiting (re)transmission, or queued flow-control requests.
    QPs dead on a protection error park their unacked slots until
    ``reestablish_qp`` — they are not live work (retrying can never
    succeed); retry-exhaustion QPs keep replaying their surviving slots
    exactly as before."""
    net = nodes[0].net
    if not net.quiescent():
        return True
    for nd in nodes:
        if any(nd.retx.outstanding(q) for q in nd.retx.slots
               if q not in nd._fatal_qps):
            return True
        if any(nd.fc.queue_depth(q) for q in range(len(nd.fc.pending))
               if nd.fc.pending[q] and q not in nd._fatal_qps):
            return True
    return False


def run_network(nodes: List[RdmaNode], max_ticks: int = 100_000,
                idle_done: int = 8, *,
                epoch_mode: Optional[str] = None) -> int:
    """Drive the simulation until quiescent: no packets in flight, no
    unacked payloads awaiting (re)transmission, no queued flow-control
    requests.  Returns ticks elapsed.

    ``epoch_mode="fused"`` (or env ``BALBOA_EPOCH_MODE=fused``) runs
    whole epochs inside one jitted ``while_loop`` on device
    (``repro.core.fused``) instead of round-tripping device<->host every
    tick; any world the fused twin does not model falls back to per-tick
    stepping, one tick at a time, re-attempting fusion after each (e.g.
    an in-flight READ_REQUEST unfuses only until it is ACKed).  The
    fused path is bit-identical to per-tick stepping — pinned by
    ``tests/test_fused_core.py`` — except that interleaving fallback
    ticks with fused epochs may re-run up to ``idle_done`` quiescent
    (no-op) ticks, shifting only ``net.now`` and the returned count."""
    mode = epoch_mode or os.environ.get("BALBOA_EPOCH_MODE") or "tick"
    if mode not in ("tick", "fused"):
        raise ValueError(f"unknown epoch_mode {mode!r}; "
                         f"choose from ('tick', 'fused')")
    if mode == "fused":
        from repro.core import fused as _fused
        t, idle = 0, 0
        while t < max_ticks:
            res = _fused.run_fused_epoch(nodes, max_ticks=max_ticks - t,
                                         idle_done=idle_done)
            if res is None:                      # unfusable: oracle tick
                step_network(nodes)
                t += 1
                if network_pending(nodes):
                    idle = 0
                else:
                    idle += 1
                    if idle >= idle_done:
                        return t - 1
                continue
            t += res["steps"]
            if res["idle_exit"]:
                return t - 1
        return max_ticks
    idle = 0
    for t in range(max_ticks):
        step_network(nodes)
        if network_pending(nodes):
            idle = 0
        else:
            idle += 1
            if idle >= idle_done:
                return t
    return max_ticks
