"""Collective communication over the BALBOA transport (the ML-fabric
workload the paper's opening claim is about).

The dominant data-center RDMA pattern is the collective — Hoefler et
al. name collective traffic as the stressor RoCE deployments are tuned
for — and this module schedules the classic ones across N ``RdmaNode``s
on a ``SwitchedFabric`` (or point-to-point ``Network``):

  * ring **reduce-scatter**, **allgather** and **allreduce**
    (reduce-scatter + allgather, the bandwidth-optimal schedule),
  * tree **broadcast** (binary tree rooted at any rank).

Every step rides the real verbs: tensors are chunked through
``rdma_write`` into the peers' registered buffers, receivers poll
``check_completed``, and the whole exchange therefore flows through the
batched RX engine, go-back-N retransmission, rkey protection, RX
crediting and DCQCN pacing — there is no side-channel delivery.

In-fabric reduction offload
---------------------------
``offload=True`` installs an ``AllreduceService``: a parallel-path-
style service tap relocated to the *switch* (``netsim.SwitchReducer``),
the paper's line-rate-compute-on-arriving-data model moved one hop
upstream (SHARP / SwitchML lineage).  The reduce phase then sends every
chunk straight to its owner, tagged as CHUNK contributions
(``Packet.coll_*``); the switch folds them fragment-wise with the
jitted segmented-reduce kernel (``repro.kernels.reduce``) and releases
ONE summed stream per chunk, so the owner's egress port carries 1 chunk
instead of N-1 and the N-1 sequential ring barriers collapse into a
single parallel shot — measured in ``benchmarks/fig11_allreduce.py``.

Bit-identity contract
---------------------
float32 addition commutes but does not associate, so the fold order is
pinned: chunk ``c`` is reduced as the left fold over ranks
``(c+1, c+2, ..., c+N-1, c)`` — the order the ring schedule produces
naturally, the order the switch reducer replays (``coll_src`` is the
fold position; the owner folds its own contribution last), and the
order ``allreduce_oracle`` computes in plain jnp.  Ring, offload and
oracle are therefore bit-identical, under loss and retransmission too
(property-tested in tests/test_collectives.py).

FPGA -> TPU design dual: a SmartNIC collective engine sequences DMA
descriptors against doorbells; here the schedule is host-side control
logic (python) around the jitted data planes — the RX/TX engines move
the bytes, the segmented-reduce kernel does the math.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netsim import SwitchReducer
from repro.core.rdma import RdmaNode, run_network
from repro.core.services import ParallelPathService

_DTYPES = {"float32": np.float32, "int32": np.int32}


def _default_impl() -> str:
    """Pallas on accelerators; the XLA-compiled jnp oracle on CPU (same
    convention as the service kernels — interpret mode is correctness-
    only)."""
    return "pallas" if jax.default_backend() != "cpu" else "ref"


class AllreduceService(ParallelPathService):
    """Control-plane handle of the in-fabric reduction offload.

    Architecturally a parallel-path service (paper Fig. 1 ②) whose tap
    point is the *switch* rather than the endpoint pipeline: the
    ``SwitchReducer`` it owns observes the CHUNK stream at the fabric
    hop and feeds the decision — the folded payload — back into the
    forwarding path.  This object carries the service-chain face (name,
    ``describe``) plus the control plane: the jitted reduce kernel
    configured for the group's dtype, and the QP registrations that let
    the switch synthesize transport ACKs for absorbed contributions.
    Placed in a node's chain it observes and flags nothing — the
    offload's effect arrives as summed payloads, not flag bits.
    """

    name = "allreduce-offload"

    def __init__(self, fabric, *, dtype: str = "float32",
                 impl: Optional[str] = None):
        if dtype not in _DTYPES:
            raise ValueError(f"unsupported collective dtype {dtype!r}")
        self.dtype = dtype
        self.impl = impl if impl is not None else _default_impl()
        self.reducer = SwitchReducer(self._reduce)
        fabric.attach_reducer(self.reducer)

    def _reduce(self, stack: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        return np.asarray(ops.chunk_reduce(
            jnp.asarray(stack), dtype=self.dtype, impl=self.impl))

    def register_qp(self, src_node: int, dst_node: int, src_qpn: int):
        self.reducer.register_qp(src_node, dst_node, src_qpn)

    def describe(self) -> str:
        r = self.reducer
        return (f"{self.name}[{self.dtype}/{self.impl}]: "
                f"absorbed={r.absorbed} forwarded={r.reduced_forwarded} "
                f"acks={r.acks_synthesized}")
    # node-side placement inherits the observe-nothing ParallelPathService
    # __call__ — the offload's feedback arrives as summed payloads, not
    # flag bits


@dataclasses.dataclass
class CollectiveStats:
    ticks: int = 0               # fabric ticks spent inside collectives
    transfers: int = 0           # _transfer barriers executed
    bytes_moved: int = 0         # payload bytes submitted to rdma_write

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return dataclasses.asdict(self)


class CollectiveGroup:
    """N ranks on one fabric, full-mesh connected, running ring/tree
    collectives over the verbs.

    ``nodes`` are caller-built ``RdmaNode``s (so congestion control,
    engines and service chains compose freely); rank ``r`` is
    ``nodes[r]``.  ``max_bytes`` sizes the registered buffers — it must
    hold the largest (padded) tensor exchanged.  ``offload=True``
    requires a ``SwitchedFabric`` and installs the ``AllreduceService``
    reduction offload for the reduce phase; the allgather phase always
    rides the ring.
    """

    def __init__(self, nodes: Sequence[RdmaNode], max_bytes: int, *,
                 dtype: str = "float32", offload: bool = False,
                 impl: Optional[str] = None, max_ticks: int = 300_000,
                 epoch_mode: Optional[str] = None):
        if len(nodes) < 2:
            raise ValueError("a collective group needs at least 2 ranks")
        if dtype not in _DTYPES:
            raise ValueError(f"unsupported collective dtype {dtype!r}")
        self.nodes = list(nodes)
        self.world = len(nodes)
        self.net = nodes[0].net
        self.max_bytes = max_bytes
        self.dtype = dtype
        self.impl = impl if impl is not None else _default_impl()
        self.offload = offload
        self.max_ticks = max_ticks
        self.epoch_mode = epoch_mode    # None = env BALBOA_EPOCH_MODE;
                                        # "fused" = jitted whole-epoch
                                        # transfers (core.fused)
        self.stats = CollectiveStats()
        self.recorder = None
        self._op_seq = 0
        # full QP mesh: _qpn[i][j] = rank i's QP toward rank j; writes on
        # it land in rank j's registered buffer for _qpn[j][i]
        self._qpn: List[Dict[int, int]] = [{} for _ in nodes]
        for i in range(self.world):
            for j in range(i + 1, self.world):
                qpn_ij, _, _ = nodes[i].init_rdma(max_bytes, nodes[j])
                qpn_ji = int(nodes[i].qp.tables.remote_qpn[qpn_ij])
                self._qpn[i][j] = qpn_ij
                self._qpn[j][i] = qpn_ji
        self.service: Optional[AllreduceService] = None
        if offload:
            if not hasattr(self.net, "attach_reducer"):
                raise ValueError("offload=True needs a SwitchedFabric")
            self.service = AllreduceService(self.net, dtype=dtype, impl=impl)
            for i in range(self.world):
                for j in range(self.world):
                    if i != j:
                        self.service.register_qp(
                            nodes[i].node_id, nodes[j].node_id,
                            self._qpn[i][j])

    # ------------------------------------------------------------ telemetry
    def attach_recorder(self, rec):
        """Wire a ``telemetry.FlightRecorder`` through the fabric and
        every rank; collective barriers show up as ``coll_transfer``
        spans on the group's track."""
        self.recorder = rec
        self.net.attach_recorder(rec)
        for n in self.nodes:
            n.attach_recorder(rec)

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        out = self.stats.snapshot()
        out["world"] = self.world
        if self.service is not None:
            out["reducer"] = self.service.reducer.snapshot()
        return out

    # ------------------------------------------------------------ plumbing
    def _recv_buf(self, rank: int, src: int) -> np.ndarray:
        return self.nodes[rank]._buffer_for(self._qpn[rank][src])

    def _transfer(self, sends):
        """One bulk-synchronous exchange: issue every ``(src, dst, data,
        remote_addr, coll)`` write, drive the network until quiescent,
        then verify via completion polling that every stream that should
        reach its receiver did (absorbed offload contributions complete
        at the switch, not at the receiver)."""
        expect: Dict[tuple, int] = {}
        for src, dst, data, addr, coll in sends:
            key = (dst, src)
            if key not in expect:
                expect[key] = self.nodes[dst].check_completed(
                    self._qpn[dst][src])
            delivered = coll is None or coll[1] == coll[2] - 1  # carrier?
            if delivered:
                expect[key] += self.nodes[src].expected_completions(len(data))
            self.stats.bytes_moved += len(data)
            self.nodes[src].rdma_write(self._qpn[src][dst], data,
                                       remote_addr=addr, coll=coll)
        t0 = self.net.now
        run_network(self.nodes, max_ticks=self.max_ticks,
                    epoch_mode=self.epoch_mode)
        self.stats.ticks += self.net.now - t0
        self.stats.transfers += 1
        if self.recorder is not None:
            self.recorder.record(
                t0, "coll_transfer", ("coll", f"world{self.world}"),
                dur=self.net.now - t0, sends=len(sends))
        for (dst, src), want in expect.items():
            got = self.nodes[dst].check_completed(self._qpn[dst][src])
            if got < want:
                raise RuntimeError(
                    f"collective transfer incomplete: rank {dst} polled "
                    f"{got} completions from rank {src}, expected {want} "
                    f"(QP died? {self.nodes[src].qp_errors})")

    def _fold2(self, acc_in: np.ndarray, own: np.ndarray) -> np.ndarray:
        """acc_in + own through the segmented-reduce kernel (continuing
        the canonical left fold)."""
        from repro.kernels import ops
        stack = np.stack([np.asarray(acc_in, np.uint8),
                          np.asarray(own, np.uint8)])
        return np.asarray(ops.chunk_reduce(
            jnp.asarray(stack), dtype=self.dtype, impl=self.impl))

    def _layout(self, xs: Sequence[np.ndarray]):
        npdt = _DTYPES[self.dtype]
        shape = np.asarray(xs[0]).shape
        flats = []
        for x in xs:
            a = np.asarray(x, npdt)
            if a.shape != shape:
                raise ValueError("ranks must contribute equal shapes")
            flats.append(np.ravel(a))
        n_elems = flats[0].size
        if n_elems == 0:
            raise ValueError("empty collective")
        chunk_elems = -(-n_elems // self.world)
        width = np.dtype(npdt).itemsize
        chunk_bytes = chunk_elems * width
        padded_bytes = chunk_bytes * self.world
        if padded_bytes > self.max_bytes:
            raise ValueError(f"tensor needs {padded_bytes} B buffers, "
                             f"group registered {self.max_bytes} B")
        work = []
        for f in flats:
            buf = np.zeros(padded_bytes, np.uint8)
            buf[:n_elems * width] = f.view(np.uint8)
            work.append(buf)
        return work, shape, n_elems, chunk_bytes

    def _region(self, c: int, chunk_bytes: int) -> slice:
        return slice(c * chunk_bytes, (c + 1) * chunk_bytes)

    # ------------------------------------------------------------ phases
    def _reduce_scatter_ring(self, work: List[np.ndarray], chunk_bytes: int):
        """N-1 neighbor steps; afterwards rank r holds chunk r fully
        reduced in canonical order (the fold travels c+1 -> ... -> c)."""
        n = self.world
        for s in range(n - 1):
            sends = []
            for r in range(n):
                c = (r - 1 - s) % n
                sends.append((r, (r + 1) % n,
                              work[r][self._region(c, chunk_bytes)],
                              c * chunk_bytes, None))
            self._transfer(sends)
            for r in range(n):
                c = (r - 2 - s) % n
                reg = self._region(c, chunk_bytes)
                inc = self._recv_buf(r, (r - 1) % n)[reg]
                work[r][reg] = self._fold2(inc, work[r][reg])

    def _reduce_scatter_offload(self, work: List[np.ndarray],
                                chunk_bytes: int):
        """One parallel shot: every rank sends each non-owned chunk to
        its owner, tagged with its canonical fold position; the switch
        folds ranks c+1..c+N-1 and the owner folds itself in last."""
        n = self.world
        self._op_seq += 1
        sends = []
        for r in range(n):
            for c in range(n):
                if c == r:
                    continue
                pos = (r - c - 1) % n
                tag = (self._op_seq << 16) | c | 0x8000_0000  # never zero
                sends.append((r, c, work[r][self._region(c, chunk_bytes)],
                              c * chunk_bytes, (tag, pos, n - 1)))
        self._transfer(sends)
        for r in range(n):
            reg = self._region(r, chunk_bytes)
            inc = self._recv_buf(r, (r - 1) % n)[reg]
            work[r][reg] = self._fold2(inc, work[r][reg])
        self.service.reducer.clear()     # fabric is quiescent: safe to gc

    def _allgather_ring(self, work: List[np.ndarray], chunk_bytes: int):
        """N-1 neighbor steps propagating each owner's chunk around."""
        n = self.world
        for s in range(n - 1):
            sends = []
            for r in range(n):
                c = (r - s) % n
                sends.append((r, (r + 1) % n,
                              work[r][self._region(c, chunk_bytes)],
                              c * chunk_bytes, None))
            self._transfer(sends)
            for r in range(n):
                c = (r - 1 - s) % n
                reg = self._region(c, chunk_bytes)
                work[r][reg] = self._recv_buf(r, (r - 1) % n)[reg].copy()

    # ------------------------------------------------------------ verbs
    def reduce_scatter(self, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Rank r gets its owned reduced shard (chunk r, trimmed to the
        unpadded element range)."""
        work, _, n_elems, chunk_bytes = self._layout(xs)
        if self.offload:
            self._reduce_scatter_offload(work, chunk_bytes)
        else:
            self._reduce_scatter_ring(work, chunk_bytes)
        npdt = _DTYPES[self.dtype]
        width = np.dtype(npdt).itemsize
        out = []
        for r in range(self.world):
            lo = r * chunk_bytes
            hi = min((r + 1) * chunk_bytes, n_elems * width)
            out.append(work[r][lo:max(hi, lo)].copy().view(npdt))
        return out

    def allgather(self, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Every rank contributes an equal-shaped shard; every rank gets
        the rank-order concatenation."""
        npdt = _DTYPES[self.dtype]
        shards = [np.ravel(np.asarray(x, npdt)) for x in xs]
        n = self.world
        if any(s.size != shards[0].size for s in shards):
            raise ValueError("allgather shards must be equal-sized")
        chunk_bytes = shards[0].size * np.dtype(npdt).itemsize
        if chunk_bytes * n > self.max_bytes:
            raise ValueError("allgather result exceeds registered buffers")
        work = []
        for r in range(n):
            buf = np.zeros(chunk_bytes * n, np.uint8)
            buf[self._region(r, chunk_bytes)] = shards[r].view(np.uint8)
            work.append(buf)
        self._allgather_ring(work, chunk_bytes)
        return [w.view(npdt).copy() for w in work]

    def allreduce(self, xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Element-wise sum across ranks, every rank gets the result —
        ring reduce-scatter (or the in-fabric offload) + ring allgather.
        Bit-identical to ``allreduce_oracle`` in either mode."""
        work, shape, n_elems, chunk_bytes = self._layout(xs)
        if self.offload:
            self._reduce_scatter_offload(work, chunk_bytes)
        else:
            self._reduce_scatter_ring(work, chunk_bytes)
        self._allgather_ring(work, chunk_bytes)
        npdt = _DTYPES[self.dtype]
        width = np.dtype(npdt).itemsize
        return [w[:n_elems * width].copy().view(npdt).reshape(shape)
                for w in work]

    def broadcast(self, x: np.ndarray, root: int = 0) -> List[np.ndarray]:
        """Binary-tree broadcast from ``root``; returns every rank's
        copy (bit-identical to the input)."""
        npdt = _DTYPES[self.dtype]
        data = np.ravel(np.asarray(x, npdt))
        nbytes = data.size * np.dtype(npdt).itemsize
        if nbytes > self.max_bytes:
            raise ValueError("broadcast tensor exceeds registered buffers")
        n = self.world
        actual = lambda v: (root + v) % n        # virtual rank -> rank
        have: Dict[int, np.ndarray] = {0: data.view(np.uint8)}
        frontier = [0]
        while frontier:
            sends, recvs = [], []
            for v in frontier:
                for child in (2 * v + 1, 2 * v + 2):
                    if child < n:
                        sends.append((actual(v), actual(child),
                                      have[v], 0, None))
                        recvs.append((child, v))
            if not sends:
                break
            self._transfer(sends)
            frontier = []
            for child, parent in recvs:
                have[child] = self._recv_buf(
                    actual(child), actual(parent))[:nbytes].copy()
                frontier.append(child)
        shape = np.asarray(x).shape
        return [have[(r - root) % n].view(npdt).reshape(shape).copy()
                for r in range(n)]


def allreduce_oracle(xs: Sequence[np.ndarray], dtype: str = "float32"
                     ) -> np.ndarray:
    """The jnp oracle the transport must reproduce bit-for-bit: chunk
    ``c`` (of N = len(xs) chunks) is the left fold of the ranks in
    rotation order ``c+1, ..., c+N-1, c`` — exactly the association the
    ring schedule and the switch reducer compute.  For int32 (exact
    arithmetic) this equals a plain ``jnp.sum``."""
    npdt = _DTYPES[dtype]
    n = len(xs)
    flats = [np.ravel(np.asarray(x, npdt)) for x in xs]
    n_elems = flats[0].size
    chunk_elems = -(-n_elems // n)
    padded = chunk_elems * n
    cols = jnp.stack([jnp.pad(jnp.asarray(f), (0, padded - n_elems))
                      for f in flats])                     # (N, P)
    chunks = []
    for c in range(n):
        reg = cols[:, c * chunk_elems:(c + 1) * chunk_elems]
        acc = reg[(c + 1) % n]
        for k in range(2, n + 1):
            acc = acc + reg[(c + k) % n]
        chunks.append(acc)
    out = jnp.concatenate(chunks)[:n_elems]
    return np.asarray(out).reshape(np.asarray(xs[0]).shape)


def make_ring_group(world: int, max_bytes: int, *,
                    fabric_cfg=None, dtype: str = "float32",
                    offload: bool = False,
                    congestion_control: str = "ack_clocked",
                    engine: str = "batched", fc_window: int = 16,
                    impl: Optional[str] = None,
                    max_ticks: int = 300_000,
                    rx_mode: str = "go_back_n",
                    path_select: Optional[str] = None,
                    epoch_mode: Optional[str] = None):
    """Convenience constructor: ``world`` nodes on a fresh fabric
    (ports = ranks), mesh-connected into a ``CollectiveGroup``.
    Returns the group (nodes at ``group.nodes``).

    ``fabric_cfg`` may be a ``FabricConfig`` (single-switch star, the
    default) or a ``ClosConfig`` (leaf-spine multipath — pair it with
    ``rx_mode="selective_repeat"`` / ``path_select="spray"`` so the
    collective's neighbor exchanges tolerate the fabric's reorder).
    """
    from repro.core.flow_control import DcqcnConfig
    from repro.core.netsim import (ClosConfig, ClosFabric, FabricConfig,
                                   SwitchedFabric, _per_port)

    cfg = fabric_cfg if fabric_cfg is not None else FabricConfig(
        port_bandwidth=4, port_delay=2, queue_capacity=48, seed=7)
    if isinstance(cfg, ClosConfig):
        fabric = ClosFabric(world, cfg)
    else:
        fabric = SwitchedFabric(world, cfg)
    line = float(_per_port(cfg.port_bandwidth, world)[0])
    dcqcn = DcqcnConfig(line_rate=line, initial_rate=line / 4)
    nodes = [RdmaNode(i, fabric, fc_window=fc_window, engine=engine,
                      congestion_control=congestion_control, dcqcn=dcqcn,
                      rx_mode=rx_mode, path_select=path_select)
             for i in range(world)]
    return CollectiveGroup(nodes, max_bytes, dtype=dtype, offload=offload,
                           impl=impl, max_ticks=max_ticks,
                           epoch_mode=epoch_mode)
