"""Service chain (paper §5): protocol enhancements attached to the
datapath.

Two placements, exactly as Fig. 1:
  * OnPathService     — transforms the payload stream in-line (①, e.g.
                        AES); its latency adds, its throughput must hold
                        line rate.
  * ParallelPathService — observes a multiplexed copy and feeds a
                        decision back to the pipeline (②, e.g. ML-DPI);
                        its latency must hide behind the packet pipeline.

FPGA -> TPU design dual: the FPGA attaches services as streaming
kernels on the AXI payload bus, one word per cycle at line rate; here
payload batches are (N, MTU) uint8 arrays and the whole chain compiles
to one jitted function — "deep pipeline" becomes "fused batch kernel" —
with each service backed by a Pallas kernel plus a pure-jnp oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OnPathService:
    """Payload transformer: (N, MTU) uint8 -> (N, MTU) uint8."""
    name = "identity"

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        return payload


class ParallelPathService:
    """Payload inspector: (N, MTU) uint8 -> (N,) int32 flags."""
    name = "null-inspect"

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        return jnp.zeros(payload.shape[0], jnp.int32)


def _default_pallas() -> bool:
    """Pallas kernels target TPU; on the CPU container they run in
    interpret mode (a Python loop over grid steps) which is for
    correctness only — timing-sensitive paths use the XLA-compiled jnp
    oracle instead."""
    return jax.default_backend() != "cpu"


@dataclasses.dataclass
class AesService(OnPathService):
    """AES-128-ECB on the payload stream (paper §5.1.1).  Keys are
    exchanged out-of-band at QP setup; ECB blocks are independent, so the
    stream pipelines with zero throughput cost."""
    key: np.ndarray = None            # (16,) uint8
    decrypt: bool = False
    use_pallas: bool = dataclasses.field(default_factory=_default_pallas)
    name: str = "aes-ecb"

    def __post_init__(self):
        from repro.kernels import aes_ecb as ops
        self._round_keys = ops.expand_key(np.asarray(self.key, np.uint8))

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        from repro.kernels import aes_ecb as ops
        fn = ops.aes_ecb_pallas if self.use_pallas else ops.aes_ecb_ref
        n, mtu = payload.shape
        blocks = payload.reshape(n * (mtu // 16), 16)
        out = fn(blocks, self._round_keys, decrypt=self.decrypt)
        return out.reshape(n, mtu)


@dataclasses.dataclass
class DpiService(ParallelPathService):
    """ML-based deep packet inspection (paper §5.1.2): a ternary
    fully-connected net scores every 64-byte beat; per-packet flags are
    the aggregated decision, fed back into the host-directed command."""
    params: Dict = None               # ternary MLP weights
    # decision margin over the max beat score; calibrated so benign
    # big-data payloads (max score <~0.7) never fire while fully or
    # partially embedded executables (>~1.8) do — the paper's
    # "fine-grained differentiation policy based on the ML decisions".
    threshold: float = 1.0
    use_pallas: bool = dataclasses.field(default_factory=_default_pallas)
    name: str = "ml-dpi"

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        from repro.kernels import dpi_mlp as ops
        fn = ops.dpi_scores_pallas if self.use_pallas else ops.dpi_scores_ref
        scores = fn(payload, self.params)           # (N, beats)
        beats = payload.shape[1] // 64
        beat_valid = (jnp.arange(beats)[None, :] * 64) < plen[:, None]
        agg = jnp.max(jnp.where(beat_valid, scores, -jnp.inf), axis=1)
        return (agg > self.threshold).astype(jnp.int32)


@dataclasses.dataclass
class PreprocService(OnPathService):
    """DLRM preprocessing offload (paper §8.1): Neg2Zero -> Log on dense
    features, Modulus on sparse features, at line rate on the stream.
    Payload layout: int32 little-endian, ``n_dense`` dense then
    ``n_sparse`` sparse columns per record."""
    n_dense: int = 13
    n_sparse: int = 26
    modulus: int = 100_000
    use_pallas: bool = dataclasses.field(default_factory=_default_pallas)
    name: str = "dlrm-preproc"

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        from repro.kernels import preproc as ops
        fn = ops.preproc_pallas if self.use_pallas else ops.preproc_ref
        n, mtu = payload.shape
        rec_words = self.n_dense + self.n_sparse
        words = mtu // 4
        n_rec = words // rec_words
        x = jax.lax.bitcast_convert_type(
            payload.reshape(n, words, 4), jnp.int32).reshape(n, words)
        recs = x[:, :n_rec * rec_words].reshape(n * n_rec, rec_words)
        out = fn(recs, self.n_dense, self.modulus)
        out_words = jnp.concatenate(
            [out.reshape(n, n_rec * rec_words),
             x[:, n_rec * rec_words:]], axis=1)
        out_bytes = jax.lax.bitcast_convert_type(
            out_words.reshape(n, words, 1), jnp.uint8).reshape(n, mtu)
        return out_bytes


@dataclasses.dataclass
class CrcService(ParallelPathService):
    """ICRC verification (paper §4.5) as a parallel-path check: flags
    payloads whose CRC32 does not match the attached checksum."""
    use_pallas: bool = dataclasses.field(default_factory=_default_pallas)
    name: str = "icrc"

    def __call__(self, payload: jax.Array, plen: jax.Array) -> jax.Array:
        from repro.kernels import crc32 as ops
        fn = ops.crc32_pallas if self.use_pallas else ops.crc32_ref
        return fn(payload, plen).astype(jnp.int32)


class ServiceChain:
    """Composable datapath: on-path services apply in order; parallel-path
    services run on a multiplexed copy and merge decision flags into the
    host-directed command.  ``process`` is one jitted function over the
    packet batch.

    Placement matters (paper Fig. 1): ``parallel`` inspectors tap the
    stream as it arrives (before on-path transforms — e.g. ICRC over the
    wire bytes); ``parallel_after`` inspectors tap it after the on-path
    services (e.g. DPI over the *decrypted* payload of an encrypted
    flow)."""

    MAX_INSPECTORS = 32          # decision flags pack into one 32-bit word

    def __init__(self, on_path: Sequence[OnPathService] = (),
                 parallel: Sequence[ParallelPathService] = (),
                 parallel_after: Sequence[ParallelPathService] = ()):
        self.on_path = list(on_path)
        self.parallel = list(parallel)
        self.parallel_after = list(parallel_after)
        inspectors = self.parallel + self.parallel_after
        if len(inspectors) > self.MAX_INSPECTORS:
            raise ValueError(
                f"{len(inspectors)} parallel-path inspectors; the "
                f"host-directed command carries at most "
                f"{self.MAX_INSPECTORS} decision flag bits")
        # explicit flag-bit layout: bit i belongs to inspectors[i]
        # (pre-transform taps first, then post-transform taps), exposed
        # by *name* so consumers never depend on insertion order.  Bits
        # are assigned by position, so the same inspector instance
        # tapping both placements gets two distinct bits.
        self._par_bits = list(range(len(self.parallel)))
        self._par_after_bits = list(range(len(self.parallel),
                                          len(inspectors)))
        self.flag_bits: Dict[str, int] = {}
        for bit, svc in enumerate(inspectors):
            name = svc.name
            if name in self.flag_bits:       # duplicate service names
                name = f"{name}@{bit}"
            self.flag_bits[name] = bit
        self._jitted = jax.jit(self._process)

    def _process(self, payload, plen):
        flags = jnp.zeros(payload.shape[0], jnp.int32)
        for svc, bit in zip(self.parallel, self._par_bits):
            flags = flags | (svc(payload, plen) << bit)
        out = payload
        for svc in self.on_path:
            out = svc(out, plen)
        for svc, bit in zip(self.parallel_after, self._par_after_bits):
            flags = flags | (svc(out, plen) << bit)
        return out, flags

    def process(self, payload, plen):
        return self._jitted(payload, plen)

    def describe(self) -> str:
        on = " -> ".join(s.name for s in self.on_path) or "(none)"
        par = ", ".join(s.name for s in self.parallel + self.parallel_after) \
            or "(none)"
        return f"on-path: {on}; parallel-path: {par}"
