"""BALBOA ingest: disaggregated storage -> RDMA -> service chain ->
sharded device buffers (paper §8's RDMA-to-GPU path, generalized into
the training framework's data plane).

Two data planes share one topology (a trainer node + N storage
replicas):

**Streaming plane** (``stream_shard`` / ``fetch_shard_streaming``, the
line-rate path).  Each shard is STRIPED across every replica over
concurrent QPs; the network is ticked incrementally (``step_network``)
and each QP's contiguous-byte completion watermark
(``RdmaNode.rx_progress``) is polled between ticks, so fragment tiles
are handed to the jitted kernels (``tile_to_batch`` -> e.g.
``preproc_pallas`` via ``make_dlrm_tile_decoder``) the moment their
bytes are acknowledged — process-as-it-arrives, not store-and-forward.
Tiles land in a pre-allocated, pre-sharded ``DeviceLandingZone``; the
host never decodes or copies payload bytes (the only host-side touch is
the registered-buffer -> device DMA, ``jnp.asarray`` of the buffer
view), which ``tests/test_ingest_stream.py`` enforces by poisoning
``decode_fn``.  Fault tolerance is per-stripe: a replica that stops
answering (QP retry-budget exhaustion or a stalled watermark) costs a
re-fetch of ONLY its stripes on a surviving replica's QP
(``reestablish_qp``), while healthy stripes keep streaming.

**Synchronous plane** (``fetch_shard``, the store-and-forward baseline).
One blocking READ of the whole shard from one replica, decoded on the
HOST via ``decode_fn`` (payload bytes are copied — counted in
``host_payload_bytes``), then ``device_put``.  Kept as the failover
oracle and as the baseline ``benchmarks/fig10_dlrm.py`` measures the
streaming plane against; the whole-shard replica failover of earlier
PRs lives here unchanged.

FPGA -> TPU design dual: on the FPGA the preprocessed stream DMAs
straight from the NIC into GPU memory behind a deep pipeline; here the
deep pipeline's overlap becomes the tick/watermark interleave —
transport ticks and per-tile kernel calls alternate on the timeline, so
preprocessing is hidden behind the transfer (measured as
``StreamReport.overlap_efficiency``) — and "DMA-to-GPU" becomes
registered buffers whose accepted bytes move straight into the sharded
device mesh.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet as pk
from repro.core.flow_control import CreditLedger
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network, step_network
from repro.core.services import ServiceChain


@dataclasses.dataclass
class IngestConfig:
    batch_bytes: int = 1 << 20
    straggler_timeout_ticks: int = 5000
    n_storage_nodes: int = 2          # replicas (striping + failover)
    loss_prob: float = 0.0
    latency_ticks: int = 4
    prefetch: int = 2                 # double buffering depth (legacy plane)
    # --- streaming data plane ---------------------------------------
    qps_per_node: int = 1             # concurrent QPs per storage replica
    tile_pkts: int = 4                # fragment-tile size handed to kernels
    link_bw_pkts_per_tick: int = 0    # per-link shaping (0 = unshaped)
    stall_ticks: Optional[int] = None  # per-stripe no-progress failover
                                       # window (None = straggler timeout)
    engine: str = "batched"           # RX engine of every node
    # --- topology / multipath ---------------------------------------
    topology: str = "p2p"             # | "clos" (leaf-spine multipath)
    clos_cfg: Optional[object] = None  # netsim.ClosConfig when "clos"
    rx_mode: str = "go_back_n"        # | "selective_repeat"
    path_select: Optional[str] = None  # | "ecmp" | "spray"
    fc_window: Optional[int] = None   # None = 64 (16 under SR: the
                                      # burst bound must fit the bitmap)
    # None = env BALBOA_EPOCH_MODE; "fused" = whole jitted micro-epochs
    # between watermark polls (core.fused), "tick" = per-tick oracle
    epoch_mode: Optional[str] = None


@dataclasses.dataclass
class QpRef:
    """One trainer<->storage queue pair.  ``qpn_r`` comes from the
    connection table via ``RdmaNode.remote_qpn`` — never from inspecting
    the peer's buffer dict (which breaks as soon as a node holds more
    than one QP, exactly what striping requires)."""
    node: int                         # storage replica index
    qpn_l: int                        # trainer-side QPN
    qpn_r: int                        # storage-side QPN


@dataclasses.dataclass
class Stripe:
    """One contiguous packet range of a shard, served by one QP."""
    sid: int
    pkt_start: int                    # first packet index within the shard
    n_pkts: int
    nbytes: int
    node: int = -1                    # replica currently serving
    qp: int = -1                      # index into BalboaIngest.qps
    issued_tick: int = -1
    progress_tick: int = -1           # last tick the watermark advanced
    watermark: int = 0                # contiguous bytes landed
    resume: int = 0                   # byte offset the current READ
                                      # started from (tile-aligned; >0
                                      # after a mid-stripe failover)
    tiles_emitted: int = 0
    refetches: int = 0
    attempts: Tuple[int, ...] = ()    # replicas tried so far
    done: bool = False
    ledger: Optional[CreditLedger] = None   # RX credit view at completion


@dataclasses.dataclass
class StreamReport:
    """What one streamed shard fetch did, for benches and tests."""
    index: int
    nbytes: int
    ticks: int                        # total ticks the stream took
    transport_done_tick: int          # tick (relative) the last byte landed
    tiles: int
    tiles_overlapped: int             # tiles consumed while bytes in flight
    refetches: int
    stripes: List[Stripe]
    events: List[Tuple]               # ("issue"|"tile"|"done"|"refetch",
                                      #  tick, stripe, ...) in time order

    @property
    def goodput_bytes_per_tick(self) -> float:
        return self.nbytes / max(self.ticks, 1)

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of tile work issued while transport was still in
        flight — 1.0 means preprocessing fully hidden behind the wire."""
        return self.tiles_overlapped / max(self.tiles, 1)

    @property
    def ledgers(self) -> Dict[int, CreditLedger]:
        """Per-stripe RX credit ledgers (stripe id -> ledger view)."""
        return {s.sid: s.ledger for s in self.stripes if s.ledger}

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"index": self.index, "nbytes": self.nbytes,
                "ticks": self.ticks,
                "transport_done_tick": self.transport_done_tick,
                "tiles": self.tiles,
                "tiles_overlapped": self.tiles_overlapped,
                "refetches": self.refetches,
                "goodput_bytes_per_tick": self.goodput_bytes_per_tick,
                "overlap_efficiency": self.overlap_efficiency,
                "stripes": {s.sid: s.ledger.snapshot()
                            for s in self.stripes if s.ledger}}


class DisaggregatedStorage:
    """A remote storage node: shards live in its registered buffers."""

    def __init__(self, node: RdmaNode, shard_fn: Callable[[int], np.ndarray]):
        self.node = node
        self.shard_fn = shard_fn      # shard index -> bytes
        self._cache: Tuple[Optional[int], Optional[np.ndarray]] = (None, None)

    def shard_bytes(self, index: int) -> np.ndarray:
        if self._cache[0] != index:
            self._cache = (index, np.asarray(self.shard_fn(index), np.uint8))
        return self._cache[1]

    def load_shard(self, buf: np.ndarray, index: int) -> int:
        data = self.shard_bytes(index)
        n = min(len(data), len(buf))
        buf[:n] = data[:n]
        return n

    def load_stripe(self, buf: np.ndarray, index: int,
                    byte_start: int, nbytes: int) -> int:
        """Serve one stripe: place its bytes at the base of the QP's
        registered buffer (the stripe READ addresses from 0)."""
        chunk = self.shard_bytes(index)[byte_start:byte_start + nbytes]
        buf[:len(chunk)] = chunk
        return len(chunk)


def _place_tile_impl(buf: jax.Array, tile: jax.Array,
                     row: jax.Array) -> jax.Array:
    idx = (row,) + (0,) * (buf.ndim - 1)
    return jax.lax.dynamic_update_slice(buf, tile, idx)


# the stale landing buffer is donated so accelerator backends update it
# in place; donation is unimplemented on CPU (would only warn)
_place_tile = jax.jit(
    _place_tile_impl,
    donate_argnums=() if jax.default_backend() == "cpu" else (0,))


class DeviceLandingZone:
    """Pre-registered, pre-sharded device buffers streamed tiles land in
    — the software stand-in for the paper's NIC->GPU DMA region.  Buffers
    are allocated (and placed under their shardings) ONCE per shard;
    each completed tile is placed with a jitted ``dynamic_update_slice``
    whose tile shapes are fixed, so mid-stream placement never
    recompiles and never bounces through a host array.  The stale buffer
    is DONATED, so on accelerator backends the update is genuinely in
    place (XLA aliases output to input); the CPU backend cannot alias
    and pays one buffer copy per placement instead."""

    def __init__(self, specs: Dict[str, Tuple[Tuple[int, ...], np.dtype]],
                 shardings: Optional[Dict] = None):
        self.bufs: Dict[str, jax.Array] = {}
        for k, (shape, dtype) in specs.items():
            z = jnp.zeros(shape, dtype)
            shd = (shardings or {}).get(k)
            self.bufs[k] = jax.device_put(z, shd) if shd is not None \
                else jax.device_put(z)

    _place = staticmethod(_place_tile)

    def place(self, key: str, tile: jax.Array, row_offset: int):
        self.bufs[key] = self._place(self.bufs[key], tile,
                                     jnp.asarray(row_offset, jnp.int32))

    def arrays(self) -> Dict[str, jax.Array]:
        return dict(self.bufs)


def make_dlrm_tile_decoder(n_dense: int, n_sparse: int,
                           modulus: Optional[int] = None, *,
                           mtu: int = pk.MTU) -> Callable:
    """Device-side tile -> batch transform for the record-aligned DLRM
    stream layout (``synthetic.encode_dlrm_packets``).

    With ``modulus`` set the tile carries RAW records and is preprocessed
    here, per tile, with the fused Pallas kernel — the tile-granular
    process-as-it-arrives path.  With ``modulus=None`` the on-path
    ``PreprocService`` already rewrote the records inside the RX
    pipeline and the decoder only splits columns.  Either way the whole
    transform is one jitted function over a FIXED ``(tile_pkts, MTU)``
    shape: nothing here runs on the host."""
    from repro.kernels.preproc import preproc_pallas
    rec_w = n_dense + n_sparse
    words = mtu // 4
    rpp = words // rec_w              # records per packet

    @jax.jit
    def decode(tile_u8: jax.Array) -> Dict[str, jax.Array]:
        p = tile_u8.shape[0]
        w = jax.lax.bitcast_convert_type(
            tile_u8.reshape(p, words, 4), jnp.int32).reshape(p, words)
        recs = w[:, :rpp * rec_w].reshape(p * rpp, rec_w)
        if modulus is not None:
            recs = preproc_pallas(recs, n_dense, modulus)
        dense = jax.lax.bitcast_convert_type(recs[:, :n_dense], jnp.float32)
        sparse = recs[:, n_dense:]
        return {"dense": dense, "sparse": sparse}

    return decode


class BalboaIngest:
    """Streams shards from storage to device through the service chain."""

    def __init__(self, cfg: IngestConfig, services: Optional[ServiceChain],
                 shard_fn: Callable[[int], np.ndarray],
                 decode_fn: Optional[Callable] = None,
                 shardings: Optional[Dict] = None,
                 tile_to_batch: Optional[Callable] = None):
        self.cfg = cfg
        n_nodes = 1 + cfg.n_storage_nodes
        if cfg.topology == "clos":
            from repro.core.netsim import ClosConfig, ClosFabric
            ccfg = cfg.clos_cfg if cfg.clos_cfg is not None else ClosConfig(
                nodes_per_leaf=1, n_spines=2, port_delay=1,
                spine_delay=(1, 5), loss_prob=cfg.loss_prob, seed=3,
                path_mode=cfg.path_select or "ecmp")
            self.net = ClosFabric(n_nodes, ccfg)
        elif cfg.topology == "p2p":
            self.net = Network(n_nodes, LinkConfig(
                loss_prob=cfg.loss_prob, latency_ticks=cfg.latency_ticks,
                bandwidth_pkts_per_tick=cfg.link_bw_pkts_per_tick, seed=3))
        else:
            raise ValueError(f"unknown topology {cfg.topology!r}; "
                             f"choose from ('p2p', 'clos')")
        fc_window = cfg.fc_window if cfg.fc_window is not None else (
            16 if cfg.rx_mode == "selective_repeat" else 64)
        self._node_kw = dict(engine=cfg.engine, rx_mode=cfg.rx_mode,
                             path_select=cfg.path_select,
                             fc_window=fc_window)
        self.trainer = RdmaNode(0, self.net, services=services,
                                **self._node_kw)
        mtu = self.trainer.mtu
        tile_bytes = cfg.tile_pkts * mtu
        # QP buffers hold a full shard (legacy plane) rounded up to whole
        # tiles, so a fixed-shape tile view never runs off the end
        self._buf_bytes = -(-cfg.batch_bytes // tile_bytes) * tile_bytes
        self.storage: List[DisaggregatedStorage] = []
        self.qps: List[QpRef] = []
        self._node_qps: List[List[int]] = []   # node -> indices into qps
        for i in range(cfg.n_storage_nodes):
            node = RdmaNode(1 + i, self.net, **self._node_kw)
            self.storage.append(DisaggregatedStorage(node, shard_fn))
            mine = []
            for _ in range(cfg.qps_per_node):
                qpn_l, _rkey, _buf = self.trainer.init_rdma(
                    self._buf_bytes, node)
                mine.append(len(self.qps))
                self.qps.append(QpRef(i, qpn_l,
                                      self.trainer.remote_qpn(qpn_l)))
            self._node_qps.append(mine)
        self.shard_fn = shard_fn
        self.decode_fn = decode_fn
        self.shardings = shardings
        self.tile_to_batch = tile_to_batch
        self.refetches = 0
        self.recorder = None
        self._qp_epoch: Dict[int, int] = {}    # qpn_l -> failover epoch
        # payload bytes that crossed a host-side decode copy (legacy
        # plane only; the streaming plane keeps this at 0 — test-enforced)
        self.host_payload_bytes = 0
        self._rows_per_pkt: Optional[Dict[str, int]] = None
        self._tile_dtypes: Optional[Dict[str, np.dtype]] = None

    _EPOCH_PSN_STRIDE = 1 << 16

    # ------------------------------------------------------- telemetry
    def attach_recorder(self, rec):
        """Wire a ``telemetry.FlightRecorder`` through the whole ingest
        stack: fabric hops, trainer/storage QP events, and the stream
        lifecycle (issue/tile/done/refetch) on per-stripe tracks."""
        self.recorder = rec
        self.net.attach_recorder(rec)
        self.trainer.attach_recorder(rec)
        for s in self.storage:
            s.node.attach_recorder(rec)

    def _rec(self, kind: str, sid: int, **attrs):
        if self.recorder is not None:
            self.recorder.record(self.net.now, kind, ("stripe", sid),
                                 **attrs)

    def snapshot(self) -> dict:
        """Common telemetry shape (see ``telemetry.MetricRegistry``)."""
        return {"refetches": self.refetches,
                "host_payload_bytes": self.host_payload_bytes,
                "n_qps": len(self.qps),
                "n_storage_nodes": len(self.storage)}

    def _failover_reestablish(self, qp: QpRef):
        """Tear down BOTH ends of the pair and restart them in a fresh
        PSN epoch (paper §4.6's out-of-band re-exchange).  A one-sided
        reset is unsound: a still-alive peer (transient outage) keeps
        replaying the old transfer's packets from its retransmit ring
        with exactly the PSNs a zero-reset trainer would expect, which
        silently delivers STALE payload into the next transfer on this
        QP.  The epoch stride additionally keeps packets already on the
        wire outside the new PSN window, where the RX pipeline discards
        them as duplicates instead of accepting them as data."""
        epoch = self._qp_epoch.get(qp.qpn_l, 0) + 1
        self._qp_epoch[qp.qpn_l] = epoch
        start_psn = (epoch * self._EPOCH_PSN_STRIDE) & pk.PSN_MASK
        self.trainer.reestablish_qp(qp.qpn_l, start_psn)
        self.storage[qp.node].node.reestablish_qp(qp.qpn_r, start_psn)

    # ------------------------------------------------ streaming plane
    def plan_stripes(self, nbytes: int) -> List[Stripe]:
        """Stripe a shard of ``nbytes`` across all QPs: contiguous
        packet ranges, one stripe per QP (fewer when the shard is
        smaller than the QP fan-out)."""
        mtu = self.trainer.mtu
        n_pkts = max(1, -(-nbytes // mtu))
        n_stripes = min(len(self.qps), n_pkts)
        per = -(-n_pkts // n_stripes)
        stripes = []
        for s in range(n_stripes):
            lo = s * per
            if lo >= n_pkts:
                break
            cnt = min(per, n_pkts - lo)
            stripes.append(Stripe(
                sid=len(stripes), pkt_start=lo, n_pkts=cnt,
                nbytes=min(cnt * mtu, nbytes - lo * mtu)))
        return stripes

    def _advance(self, nodes, active, stall, on_tick, rel, deadline):
        """One transport advance of the streaming loop: a single oracle
        tick, or — in fused epoch mode — one jitted micro-epoch
        (``core.fused``) armed with a completion watermark per active
        stripe, so the device loop exits the moment any stripe crosses
        its next tile boundary and the host polls exactly then instead
        of every tick.  The epoch budget is clamped so the per-stripe
        stall detector and the shard deadline still fire on time; any
        unfusable world (an in-flight READ_REQUEST, a dead QP) falls
        back to per-tick stepping and re-attempts fusion next call."""
        cfg = self.cfg
        mode = cfg.epoch_mode or os.environ.get("BALBOA_EPOCH_MODE")
        if mode == "fused" and on_tick is None and active:
            tile_bytes = cfg.tile_pkts * self.trainer.mtu
            wms: Dict[Tuple[int, int], int] = {}
            budget = deadline - rel() + 1
            for qp_idx, stripe in active.items():
                qp = self.qps[qp_idx]
                lo = stripe.tiles_emitted * tile_bytes
                hi = min(lo + tile_bytes, stripe.nbytes)
                wms[(self.trainer.node_id, qp.qpn_l)] = max(
                    hi - stripe.resume, 1)
                budget = min(budget, stall + 1
                             - (self.net.now - stripe.progress_tick))
            if budget > 1:
                from repro.core import fused
                res = fused.run_fused_epoch(nodes, max_ticks=budget,
                                            idle_done=8, watermarks=wms)
                if res is not None:
                    return
        step_network(nodes)

    def stream_shard(self, index: int,
                     consume_tile: Optional[Callable] = None,
                     on_tick: Optional[Callable[[int], None]] = None
                     ) -> StreamReport:
        """Striped, incremental fetch of shard ``index``.

        ``consume_tile(stripe, tile_idx, dev_tile, n_valid_pkts)`` fires
        the moment a tile's bytes are contiguously acknowledged —
        ``dev_tile`` is the fixed-shape ``(tile_pkts, MTU)`` uint8 device
        array DMA'd straight from the registered buffer.  ``on_tick`` is
        a test/fault-injection hook called once per network tick."""
        cfg = self.cfg
        mtu = self.trainer.mtu
        tile_bytes = cfg.tile_pkts * mtu
        nbytes = int(self.storage[0].shard_bytes(index).size)
        if nbytes > self._buf_bytes:
            raise ValueError(f"shard {index}: {nbytes} B exceeds the "
                             f"registered window {self._buf_bytes} B")
        stripes = self.plan_stripes(nbytes)
        stall = cfg.stall_ticks if cfg.stall_ticks is not None \
            else cfg.straggler_timeout_ticks
        n_pkts_total = max(1, -(-nbytes // mtu))
        deadline = stall * (cfg.n_storage_nodes + 2) + 32 * n_pkts_total
        nodes = [self.trainer] + [s.node for s in self.storage]
        pending: collections.deque = collections.deque(stripes)
        active: Dict[int, Stripe] = {}          # qp index -> stripe
        events: List[Tuple] = []
        t0 = self.net.now
        tiles_total = 0

        def rel() -> int:
            return self.net.now - t0

        def issue(stripe: Stripe, qp_idx: int):
            qp = self.qps[qp_idx]
            st = self.storage[qp.node]
            # tiles already handed downstream are valid (replicas serve
            # identical bytes) — a refetch READs only the un-consumed
            # suffix, resuming at the last emitted tile boundary
            stripe.resume = min(stripe.tiles_emitted * tile_bytes,
                                stripe.nbytes)
            st.load_stripe(st.node._qp_buffer[qp.qpn_r][1], index,
                           stripe.pkt_start * mtu + stripe.resume,
                           stripe.n_pkts * mtu - stripe.resume)
            self.trainer.reset_rx_progress(qp.qpn_l)
            self.trainer.rdma_read(qp.qpn_l, stripe.nbytes - stripe.resume)
            stripe.node, stripe.qp = qp.node, qp_idx
            stripe.issued_tick = stripe.progress_tick = self.net.now
            stripe.watermark = stripe.resume
            stripe.attempts += (qp.node,)
            active[qp_idx] = stripe
            events.append(("issue", rel(), stripe.sid, qp.node))
            self._rec("stream_issue", stripe.sid, node=qp.node,
                      resume=stripe.resume)

        def pick_qp(stripe: Stripe) -> Optional[int]:
            for qp_idx, qp in enumerate(self.qps):
                if qp_idx not in active and qp.node not in stripe.attempts:
                    return qp_idx
            return None

        while pending or active:
            for stripe in list(pending):
                qp_idx = pick_qp(stripe)
                if qp_idx is not None:
                    pending.remove(stripe)
                    issue(stripe, qp_idx)
            self._advance(nodes, active, stall, on_tick, rel, deadline)
            if on_tick is not None:
                on_tick(rel())
            for qp_idx, stripe in list(active.items()):
                qp = self.qps[qp_idx]
                # the READ addresses from the resume offset, so the
                # stripe-relative frontier is resume + QP watermark
                wm = stripe.resume + self.trainer.rx_progress(qp.qpn_l)
                if wm > stripe.watermark:
                    stripe.watermark = wm
                    stripe.progress_tick = self.net.now
                # hand over every newly completed fragment tile
                while True:
                    lo = stripe.tiles_emitted * tile_bytes
                    if lo >= stripe.nbytes:
                        break
                    hi = min(lo + tile_bytes, stripe.nbytes)
                    if stripe.watermark < hi:
                        break
                    if consume_tile is not None:
                        buf = self.trainer._qp_buffer[qp.qpn_l][1]
                        # the one and only payload movement: registered
                        # buffer -> device, fixed tile shape, no host
                        # transform or decode in between.  copy=True is
                        # load-bearing: the CPU backend would otherwise
                        # ALIAS the registered buffer, and a later
                        # refetch rewriting it would corrupt tiles
                        # already handed downstream
                        off = lo - stripe.resume   # buffer-relative
                        dev = jnp.array(
                            buf[off:off + tile_bytes].reshape(cfg.tile_pkts,
                                                              mtu),
                            copy=True)
                        consume_tile(stripe, stripe.tiles_emitted, dev,
                                     -(-(hi - lo) // mtu))
                    events.append(("tile", rel(), stripe.sid,
                                   stripe.tiles_emitted))
                    self._rec("stream_tile", stripe.sid,
                              tile=stripe.tiles_emitted)
                    stripe.tiles_emitted += 1
                    tiles_total += 1
                if stripe.watermark >= stripe.nbytes:
                    stripe.done = True
                    stripe.ledger = self.trainer.credits.ledger(qp.qpn_l)
                    del active[qp_idx]
                    events.append(("done", rel(), stripe.sid))
                    self._rec("stream_done", stripe.sid,
                              tiles=stripe.tiles_emitted)
                    continue
                stalled = (self.net.now - stripe.progress_tick) > stall
                if self.trainer.qp_error(qp.qpn_l) or stalled:
                    # per-stripe failover: ONLY this stripe re-fetches,
                    # on a different replica; healthy stripes stream on
                    self.refetches += 1
                    stripe.refetches += 1
                    self._failover_reestablish(qp)
                    del active[qp_idx]
                    events.append(("refetch", rel(), stripe.sid,
                                   stripe.node))
                    self._rec("stream_refetch", stripe.sid,
                              node=stripe.node)
                    if len(set(stripe.attempts)) >= len(self.storage):
                        raise RuntimeError(
                            f"shard {index} stripe {stripe.sid}: "
                            f"all replicas failed")
                    stripe.node = stripe.qp = -1
                    pending.append(stripe)
            if rel() > deadline:
                raise RuntimeError(
                    f"shard {index}: streaming deadline exceeded "
                    f"({rel()} ticks, {len(pending) + len(active)} "
                    f"stripes unfinished)")
        done_ticks = [e[1] for e in events if e[0] == "done"]
        transport_done = max(done_ticks) if done_ticks else 0
        tiles_overlapped = sum(1 for e in events
                               if e[0] == "tile" and e[1] < transport_done)
        return StreamReport(
            index=index, nbytes=nbytes, ticks=rel(),
            transport_done_tick=transport_done, tiles=tiles_total,
            tiles_overlapped=tiles_overlapped,
            refetches=sum(s.refetches for s in stripes),
            stripes=stripes, events=events)

    def _discover_tile_specs(self):
        """One warmup call of ``tile_to_batch`` on a zero tile pins the
        per-key row counts and dtypes (and pre-compiles the transform)."""
        mtu = self.trainer.mtu
        zero = jnp.zeros((self.cfg.tile_pkts, mtu), jnp.uint8)
        out = self.tile_to_batch(zero)
        self._rows_per_pkt, self._tile_dtypes = {}, {}
        for k, v in out.items():
            if v.shape[0] % self.cfg.tile_pkts:
                raise ValueError(
                    f"tile_to_batch[{k}] rows {v.shape[0]} not a multiple "
                    f"of tile_pkts={self.cfg.tile_pkts}")
            self._rows_per_pkt[k] = v.shape[0] // self.cfg.tile_pkts
            self._tile_dtypes[k] = (v.shape[1:], v.dtype)

    def fetch_shard_streaming(self, index: int
                              ) -> Tuple[Dict[str, jax.Array], StreamReport]:
        """Stream shard ``index`` straight into a pre-sharded device
        landing zone: stripes fan out across all replicas/QPs, each tile
        is transformed on device the moment it lands, and the host never
        touches a payload byte."""
        if self.tile_to_batch is None:
            raise ValueError("streaming fetch needs tile_to_batch "
                             "(e.g. make_dlrm_tile_decoder)")
        if self._rows_per_pkt is None:
            self._discover_tile_specs()
        mtu = self.trainer.mtu
        nbytes = int(self.storage[0].shard_bytes(index).size)
        n_pkts_total = max(1, -(-nbytes // mtu))
        zone = DeviceLandingZone(
            {k: ((n_pkts_total * self._rows_per_pkt[k],) + tail, dt)
             for k, (tail, dt) in self._tile_dtypes.items()},
            self.shardings)

        def consume(stripe: Stripe, tidx: int, dev_tile: jax.Array,
                    n_valid_pkts: int):
            out = self.tile_to_batch(dev_tile)
            pkt0 = stripe.pkt_start + tidx * self.cfg.tile_pkts
            for k, arr in out.items():
                rpp = self._rows_per_pkt[k]
                zone.place(k, arr[:n_valid_pkts * rpp], pkt0 * rpp)

        report = self.stream_shard(index, consume)
        return zone.arrays(), report

    def stream_batches(self, n: int, start: int = 0
                       ) -> Iterator[Tuple[Dict[str, jax.Array],
                                           StreamReport]]:
        """Streamed iterator: transport/kernel overlap happens *inside*
        each fetch (tiles process while later stripes are on the wire),
        so no host-thread double buffering is needed."""
        for i in range(start, start + n):
            yield self.fetch_shard_streaming(i)

    # ---------------------------------------------- synchronous plane
    def fetch_shard(self, index: int) -> Dict[str, jax.Array]:
        """Store-and-forward baseline: RDMA-READ the whole shard from one
        replica, decode on the HOST, then device_put.  Kept as the
        oracle/bench baseline — the streaming plane exists to beat it."""
        if self.decode_fn is None:
            raise ValueError("fetch_shard needs decode_fn; use "
                             "fetch_shard_streaming for the host-bypass "
                             "streaming plane")
        order = [(index + r) % len(self.storage)
                 for r in range(len(self.storage))]
        for s in order:
            st = self.storage[s]
            qp = self.qps[self._node_qps[s][0]]
            nbytes = st.load_shard(st.node._qp_buffer[qp.qpn_r][1], index)
            before = self.trainer.check_completed(qp.qpn_l)
            self.trainer.rdma_read(qp.qpn_l, nbytes)
            run_network([self.trainer] + [x.node for x in self.storage],
                        max_ticks=self.cfg.straggler_timeout_ticks)
            if self.trainer.check_completed(qp.qpn_l) > before:
                raw = self.trainer._qp_buffer[qp.qpn_l][1][:nbytes]
                self.host_payload_bytes += nbytes   # the copy we eliminate
                host_batch = self.decode_fn(raw.copy())
                return self._to_device(host_batch)
            # straggler / dead peer: re-establish BOTH ends in a fresh
            # PSN epoch (clears the errored QP's retransmit ring +
            # flow-control queue on either side) and try the replica
            self.refetches += 1
            self._failover_reestablish(qp)
        raise RuntimeError(f"shard {index}: all replicas failed")

    def _to_device(self, host_batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in host_batch.items():
            shd = (self.shardings or {}).get(k)
            out[k] = jax.device_put(v, shd) if shd is not None \
                else jax.device_put(v)
        return out

    def batches(self, n: int, start: int = 0) -> Iterator[Dict]:
        """Double-buffered iterator over the synchronous plane: shard
        i+1 transfers on a worker thread while i trains."""
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(max_workers=1) as ex:
            fut = ex.submit(self.fetch_shard, start)
            for i in range(start, start + n):
                cur = fut.result()
                if i + 1 < start + n:
                    fut = ex.submit(self.fetch_shard, i + 1)
                yield cur
