"""BALBOA ingest: disaggregated storage -> RDMA -> service chain ->
sharded device buffers (paper §8's RDMA-to-GPU path, generalized into
the training framework's data plane).

The local trainer issues RDMA READs against remote storage nodes; the
payload stream passes the service chain (decrypt / DPI / preprocess) and
lands **directly in sharded jax device buffers** — the host never
touches payload bytes after the RX pipeline (the DMA-to-GPU contract).
Double buffering overlaps the next batch's transport + services with the
current train step (the framework analogue of hiding service latency
behind the packet pipeline).

Fault tolerance: a storage node that stops answering (simulated peer
death) trips the straggler timeout; the shard is re-fetched from a
replica via a fresh QP (QPManager.reestablish), and the credit ledger
provides the backpressure signal.

FPGA -> TPU design dual: on the FPGA the preprocessed stream DMAs
straight from the NIC into GPU memory; here the RX pipeline's accepted
payloads land in registered buffers that are device_put into sharded
jax arrays — "DMA-to-GPU" becomes "host-bypass into the device mesh",
with double buffering playing the role of the deep pipeline's overlap.
"""
from __future__ import annotations

import dataclasses
import threading
import queue as queue_mod
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.core import packet as pk
from repro.core.netsim import LinkConfig, Network
from repro.core.rdma import RdmaNode, run_network
from repro.core.services import ServiceChain


@dataclasses.dataclass
class IngestConfig:
    batch_bytes: int = 1 << 20
    straggler_timeout_ticks: int = 5000
    n_storage_nodes: int = 2          # replicas (straggler mitigation)
    loss_prob: float = 0.0
    latency_ticks: int = 4
    prefetch: int = 2                 # double buffering depth


class DisaggregatedStorage:
    """A remote storage node: shards live in its registered buffers."""

    def __init__(self, node: RdmaNode, shard_fn: Callable[[int], np.ndarray]):
        self.node = node
        self.shard_fn = shard_fn      # shard index -> bytes

    def load_shard(self, buf: np.ndarray, index: int) -> int:
        data = self.shard_fn(index)
        n = min(len(data), len(buf))
        buf[:n] = data[:n]
        return n


class BalboaIngest:
    """Streams shards from storage to device through the service chain."""

    def __init__(self, cfg: IngestConfig, services: Optional[ServiceChain],
                 shard_fn: Callable[[int], np.ndarray],
                 decode_fn: Callable[[np.ndarray], Dict[str, np.ndarray]],
                 shardings: Optional[Dict] = None):
        self.cfg = cfg
        n_nodes = 1 + cfg.n_storage_nodes
        self.net = Network(n_nodes, LinkConfig(
            loss_prob=cfg.loss_prob, latency_ticks=cfg.latency_ticks, seed=3))
        self.trainer = RdmaNode(0, self.net, services=services)
        self.storage: List[DisaggregatedStorage] = []
        self.qps: List[Tuple[int, int]] = []
        for i in range(cfg.n_storage_nodes):
            node = RdmaNode(1 + i, self.net)
            st = DisaggregatedStorage(node, shard_fn)
            qpn_l, _, _ = self.trainer.init_rdma(cfg.batch_bytes, node)
            # the storage-side buffer of this QP pair holds the shard
            qpn_r = max(node._qp_buffer)
            self.storage.append(st)
            self.qps.append((qpn_l, qpn_r))
        self.decode_fn = decode_fn
        self.shardings = shardings
        self.refetches = 0

    def fetch_shard(self, index: int) -> Dict[str, jax.Array]:
        """RDMA-READ one shard through the service chain to device."""
        order = [(index + r) % len(self.storage) for r in range(len(self.storage))]
        for attempt, s in enumerate(order):
            st = self.storage[s]
            qpn_l, qpn_r = self.qps[s]
            nbytes = st.load_shard(st.node._qp_buffer[qpn_r][1], index)
            before = self.trainer.check_completed(qpn_l)
            self.trainer.rdma_read(qpn_l, nbytes)
            run_network([self.trainer] + [x.node for x in self.storage],
                        max_ticks=self.cfg.straggler_timeout_ticks)
            if self.trainer.check_completed(qpn_l) > before:
                raw = self.trainer._qp_buffer[qpn_l][1][:nbytes]
                host_batch = self.decode_fn(raw.copy())
                return self._to_device(host_batch)
            # straggler / dead peer: re-establish (clears the errored
            # QP's retransmit ring + flow-control queue via
            # qp.reestablish) and try the replica
            self.refetches += 1
            self.trainer.reestablish_qp(qpn_l)
        raise RuntimeError(f"shard {index}: all replicas failed")

    def _to_device(self, host_batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in host_batch.items():
            shd = (self.shardings or {}).get(k)
            out[k] = jax.device_put(v, shd) if shd is not None \
                else jax.device_put(v)
        return out

    def batches(self, n: int, start: int = 0) -> Iterator[Dict]:
        """Double-buffered iterator: shard i+1 streams while i trains."""
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(max_workers=1) as ex:
            fut = ex.submit(self.fetch_shard, start)
            for i in range(start, start + n):
                cur = fut.result()
                if i + 1 < start + n:
                    fut = ex.submit(self.fetch_shard, i + 1)
                yield cur
