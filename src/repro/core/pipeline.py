"""Vectorized RoCE v2 packet-processing pipeline (paper §4.1, Fig. 2).

FPGA -> TPU design dual
-----------------------
The FPGA realizes one deep pipeline processing one header beat per cycle
at line rate; per-QP state (ePSN/MSN/credits) lives in BRAM tables the
pipeline reads and writes in flight.  The TPU-idiomatic dual keeps the
same per-QP tables as jax arrays, but exposes the parallelism along a
different axis: PSN checking is *inherently sequential per QP* yet
*embarrassingly parallel across QPs*, exactly the axis the paper scales
along (hundreds of QPs, Fig. 2/6).

Two jitted engines implement the same RX semantics:

``rx_pipeline``         — the per-packet oracle: one ``lax.scan`` step
                          per packet in arrival order.  Honest, simple,
                          and O(batch) sequential steps.
``rx_pipeline_batched`` — the batched multi-QP engine: packets are
                          stable-sorted by QP (preserving per-QP arrival
                          order), ranked within their QP segment, and
                          processed in *waves*: wave ``t`` handles the
                          ``t``-th packet of every QP simultaneously.
                          One wave is a fully vectorized gather ->
                          decide -> scatter over all lanes, so the
                          sequential depth is the *longest per-QP
                          segment* (≈ batch/Q for even traffic), not the
                          batch size.  Bit-identical to the oracle
                          (property-tested in tests/test_fabric.py).

Both engines share ``_rx_decide`` — the pure header FSM — so they cannot
drift apart.  The TX path gets the same treatment: ``tx_pipeline`` scans
commands; ``tx_pipeline_batched`` assigns PSN ranges with a per-QP
segmented cumulative sum.

RX semantics (paper §4.1 + §4.3):
  strip/inspect headers -> PSN check against the state table ->
  accept (emit DMA command, bump ePSN/MSN) | drop-duplicate (re-ACK) |
  drop-out-of-order (NAK, triggers remote retransmit) -> credit check
  may still drop an otherwise valid packet (peer retransmits).

All paths are jittable, differentiation-free integer programs.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import packet as pk


# Selective-repeat receive window (packets).  Bounded by the int32
# bitmap the batched engine packs per-QP state into: bit k of ``rxbit``
# marks PSN ``epsn + k`` as received-but-not-yet-cumulative, so the
# window must fit a non-negative int32.  The flow-control window (<= 16
# in every test/bench profile) must stay below this or in-window
# arrivals could land beyond the bitmap.
SR_WINDOW = 24


class RxTables(NamedTuple):
    """The jax-side mirror of QPTables fields the RX pipeline mutates."""
    epsn: jax.Array        # (Q,) int32
    msn: jax.Array         # (Q,) int32
    bytes_left: jax.Array  # (Q,) int64
    cur_vaddr: jax.Array   # (Q,) int64
    credits: jax.Array     # (Q,) int32   downstream capacity (§4.3)
    rkey: jax.Array        # (Q,) int32   registered buffer's rkey (read-only)
    rxbit: jax.Array       # (Q,) int32   SR bitmap: bit k = epsn+k received
    sr: jax.Array          # (Q,) int32   1 = selective-repeat RX mode
    # telemetry counters (monotonic, per QP).  They ride the carried
    # state exactly like the protocol fields — updated in-graph by
    # ``_rx_decide`` in both engines, harvested on the host only at
    # epoch boundaries (RdmaNode.engine_counters), so observability adds
    # zero host round-trips to a jitted epoch.
    acc_cnt: jax.Array     # (Q,) int32   payloads accepted (DMA'd)
    dup_cnt: jax.Array     # (Q,) int32   duplicates dropped (re-ACKed)
    ooo_cnt: jax.Array     # (Q,) int32   out-of-order drops (NAKed)
    cdrop_cnt: jax.Array   # (Q,) int32   credit drops
    ecn_tot: jax.Array     # (Q,) int32   CE-marked payload arrivals


class RxResult(NamedTuple):
    accept: jax.Array      # (N,) bool   payload forwarded to DMA
    dup: jax.Array         # (N,) bool   duplicate (re-ACK, no DMA)
    ooo: jax.Array         # (N,) bool   out-of-order (NAK)
    dropped_credit: jax.Array  # (N,) bool dropped for lack of credits
    rkey_err: jax.Array    # (N,) bool   RETH rkey mismatch (NAK_PROT, no DMA)
    dma_addr: jax.Array    # (N,) int64  target address for accepted payloads
    dma_len: jax.Array     # (N,) int32
    ack_psn: jax.Array     # (N,) int32  cumulative ack to send back
    ack_qpn: jax.Array     # (N,) int32
    send_ack: jax.Array    # (N,) bool
    send_nak: jax.Array    # (N,) bool
    sack: jax.Array        # (N,) int32  SR bitmap to ship with the ACK
    ecn_echo: jax.Array    # (N,) bool   CE-marked payload arrival (NP input)
    ecn_cnt: jax.Array     # (Q,) int32  CE-marked arrivals per QP this batch


# ---------------------------------------------------------------------------
# Shared header FSM (used by both the scan oracle and the batched engine)
# ---------------------------------------------------------------------------

def _rx_decide(state: Dict[str, jax.Array], p: Dict[str, jax.Array]
               ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """The pure per-packet decision function of the RX header pipeline.

    ``state`` holds the packet's QP-table row (gathered); ``p`` the
    packet header fields.  Shape-polymorphic: scalars inside the scan
    oracle, (N,) lanes inside a batched wave.  Returns the updated row
    and the per-packet outputs.
    """
    opcode = p["opcode"]
    psn = p["psn"]
    plen = p["plen"].astype(jnp.int32)
    epsn = state["epsn"]
    credits = state["credits"]

    is_payload = jnp.isin(opcode, jnp.asarray(pk.PAYLOAD_OPS, jnp.int32))
    has_reth = jnp.isin(opcode, jnp.asarray(pk.RETH_OPS, jnp.int32))
    is_last = jnp.isin(opcode, jnp.asarray(
        (pk.WRITE_LAST, pk.WRITE_ONLY, pk.READ_RESP_LAST, pk.READ_RESP_ONLY),
        jnp.int32))

    valid = p["valid"] > 0
    sr = state["sr"] > 0

    in_seq = psn == epsn
    behind = (psn - epsn) % (pk.PSN_MASK + 1) > (pk.PSN_MASK // 2)
    has_credit = credits > 0

    # ---- go-back-N verdicts (the original in-order-only FSM) ----------
    ooo_g = ~in_seq & ~behind
    # remote-access protection (§4.6): a RETH-bearing packet must present
    # the rkey of the registered buffer it targets; a mismatch is NAKed
    # with a protection error instead of being served.  Table rkey 0
    # means "nothing registered" (QPManager hands out rkeys from 1), so
    # unarmed QPs — synthetic pipeline traces — keep accepting.
    # MIDDLE/LAST fragments carry no RETH and inherit the verdict
    # implicitly: a rejected FIRST never advances ePSN, so they fall
    # out as OOO.
    rkey_ok_g = ~has_reth | (state["rkey"] == 0) | (p["rkey"] == state["rkey"])

    accept_g = is_payload & in_seq & has_credit & rkey_ok_g & valid
    dropped_g = is_payload & in_seq & ~has_credit & rkey_ok_g & valid
    rkey_err_g = is_payload & in_seq & ~rkey_ok_g & valid

    # DMA command formation (RETH starts a region; MIDDLE/LAST continue it)
    start_addr = jnp.where(has_reth, p["vaddr"], state["cur_vaddr"])
    new_epsn_g = jnp.where(accept_g, (epsn + 1) & pk.PSN_MASK, epsn)

    # ---- selective-repeat verdicts (out-of-order-tolerant window) -----
    # Any PSN inside [epsn, epsn + SR_WINDOW) is acceptable; a per-QP
    # bitmap remembers which offsets already landed.  Packets must be
    # self-contained (per-packet address/rkey, ``fragment_message(...,
    # addr_per_pkt=True)``) because an out-of-order arrival cannot lean
    # on the FIRST fragment's RETH cursor.
    d = ((psn - epsn) % (pk.PSN_MASK + 1)).astype(jnp.int32)
    in_win = ~behind & (d < SR_WINDOW)
    bit = jnp.where(
        in_win, jnp.left_shift(jnp.int32(1), jnp.minimum(d, SR_WINDOW - 1)),
        0).astype(jnp.int32)
    already = (state["rxbit"] & bit) != 0
    fresh = in_win & ~already
    # every SR payload packet carries its rkey, so protection is checked
    # on all of them (not just RETH opcodes)
    rkey_ok_s = (state["rkey"] == 0) | (p["rkey"] == state["rkey"])
    accept_s = is_payload & fresh & has_credit & rkey_ok_s & valid
    dropped_s = is_payload & fresh & ~has_credit & rkey_ok_s & valid
    rkey_err_s = is_payload & fresh & ~rkey_ok_s & valid
    dup_s = (behind | already) & is_payload
    ooo_s = ~behind & ~in_win & is_payload          # beyond the window

    # bitmap update + cumulative advance over the contiguous prefix:
    # count trailing ones of the updated bitmap via the lowest *zero*
    # bit (ctz(~bm) = popcount((~bm & -~bm) - 1); ~bm always has a set
    # bit above SR_WINDOW, so the count is <= SR_WINDOW)
    bm = state["rxbit"] | jnp.where(accept_s, bit, 0)
    inv = ~bm
    adv = jax.lax.population_count((inv & -inv) - 1).astype(jnp.int32)
    new_epsn_s = (epsn + adv) & pk.PSN_MASK
    new_rxbit_s = jax.lax.shift_right_logical(bm, adv)

    # ---- merge the two FSMs (per-QP mode select) ----------------------
    accept = jnp.where(sr, accept_s, accept_g)
    dup = jnp.where(sr, dup_s, behind & is_payload)
    ooo = jnp.where(sr, ooo_s, ooo_g & is_payload)
    dropped_credit = jnp.where(sr, dropped_s, dropped_g)
    rkey_err = jnp.where(sr, rkey_err_s, rkey_err_g)
    dma_addr = jnp.where(sr, p["vaddr"], start_addr)
    new_epsn = jnp.where(sr, new_epsn_s, new_epsn_g)
    new_rxbit = jnp.where(sr, new_rxbit_s, state["rxbit"])

    new_cur = jnp.where(accept, dma_addr + plen, state["cur_vaddr"])
    new_bytes = jnp.where(
        (has_reth | sr) & accept, p["dma_len"].astype(jnp.int32) - plen,
        jnp.where(accept, state["bytes_left"] - plen, state["bytes_left"]))
    new_msn = jnp.where(accept & is_last, state["msn"] + 1, state["msn"])
    new_credits = jnp.where(accept, credits - 1, credits)
    ecn_echo = (p["ecn"] > 0) & is_payload & valid

    new_state = {
        "epsn": new_epsn.astype(jnp.int32),
        "msn": new_msn.astype(jnp.int32),
        "bytes_left": new_bytes,
        "cur_vaddr": new_cur,
        "credits": new_credits.astype(jnp.int32),
        "rkey": state["rkey"],
        "rxbit": new_rxbit.astype(jnp.int32),
        "sr": state["sr"],
        # telemetry counters.  dup/ooo need the explicit ``valid`` gate:
        # unlike accept/credit-drop they never touch protocol state, so
        # the GBN FSM leaves them ungated for padding lanes (the batched
        # engine zeroes invalid lanes' *outputs* post-hoc, but counter
        # state must match the never-processed treatment bit-for-bit)
        "acc_cnt": state["acc_cnt"] + accept.astype(jnp.int32),
        "dup_cnt": state["dup_cnt"] + (dup & valid).astype(jnp.int32),
        "ooo_cnt": state["ooo_cnt"] + (ooo & valid).astype(jnp.int32),
        "cdrop_cnt": state["cdrop_cnt"] + dropped_credit.astype(jnp.int32),
        "ecn_tot": state["ecn_tot"] + ecn_echo.astype(jnp.int32),
    }
    out = {
        "accept": accept, "dup": dup, "ooo": ooo,
        "dropped_credit": dropped_credit, "rkey_err": rkey_err,
        "dma_addr": dma_addr.astype(jnp.int32),
        "dma_len": plen.astype(jnp.int32),
        # cumulative ACK: accepted in-order packets ack their own PSN
        # (== new_epsn - 1 for GBN); everything else re-acks the frontier
        "ack_psn": jnp.where(~sr & accept, psn,
                             (new_epsn - 1) & pk.PSN_MASK).astype(jnp.int32),
        "ack_qpn": p["qpn"].astype(jnp.int32),
        # ACK policy: ack accepted last/ack_req packets and duplicates.
        # SR additionally acks every out-of-order accept (the SACK is
        # what releases the sender's slot) and every gap-filling accept
        # that advanced the frontier by more than one.
        "send_ack": (accept & (is_last | (p["ack_req"] > 0) |
                               (sr & ((d > 0) | (adv > 1))))) | dup,
        "send_nak": ooo,
        # post-update bitmap, shipped with ACKs so the sender can
        # selectively release held slots and resend only the gaps
        "sack": jnp.where(sr, new_rxbit_s, 0).astype(jnp.int32),
        # ECN echo (DCQCN NP, §"opening the CC design space"): a CE mark
        # is congestion evidence regardless of the PSN verdict — dups and
        # credit-dropped packets crossed the congested queue too — so the
        # echo is stateless: every valid CE-marked payload packet counts.
        "ecn_echo": ecn_echo,
    }
    return new_state, out


_PKT_FIELDS = ("qpn", "opcode", "psn", "plen", "vaddr", "dma_len", "ack_req",
               "ecn", "rkey", "valid")
_STATE_FIELDS = ("epsn", "msn", "bytes_left", "cur_vaddr", "credits", "rkey",
                 "rxbit", "sr",
                 "acc_cnt", "dup_cnt", "ooo_cnt", "cdrop_cnt", "ecn_tot")
# the counter subset, exposed for epoch-boundary harvesting
COUNTER_FIELDS = ("acc_cnt", "dup_cnt", "ooo_cnt", "cdrop_cnt", "ecn_tot")


def _rx_one(tables: RxTables, p) -> Tuple[RxTables, Dict]:
    """Process one packet against the tables (scan body of the oracle)."""
    qpn = p["qpn"]
    state = {f: getattr(tables, f)[qpn] for f in _STATE_FIELDS}
    new_state, out = _rx_decide(state, p)
    tables = RxTables(**{
        f: getattr(tables, f).at[qpn].set(new_state[f])
        for f in _STATE_FIELDS})
    return tables, out


def _ensure_defaults(batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Batches built before the ECN / rkey-check eras lack those columns;
    default them to not-marked / key-0 (trace-time branch, free under
    jit; key 0 against the all-zero default rkey table passes, so legacy
    traces keep their exact decisions)."""
    n = batch["qpn"].shape[0]
    for col in ("ecn", "rkey"):
        if col not in batch:
            batch = dict(batch, **{col: jnp.zeros(n, jnp.int32)})
    return batch


@partial(jax.jit, donate_argnums=(0,))
def rx_pipeline(tables: RxTables, batch: Dict[str, jax.Array]
                ) -> Tuple[RxTables, RxResult]:
    """Per-packet oracle: scan the RX FSM over the batch in arrival
    order.  O(N) sequential steps — kept as the reference semantics the
    batched engine must reproduce bit-for-bit."""
    batch = _ensure_defaults(batch)

    def body(t, i):
        p = {k: batch[k][i] for k in _PKT_FIELDS}
        t, out = _rx_one(t, p)
        return t, out

    n = batch["qpn"].shape[0]
    n_qps = tables.epsn.shape[0]
    tables, outs = jax.lax.scan(body, tables, jnp.arange(n))
    # per-QP CE tally (the NP-side congestion signal); the oracle is
    # allowed the naive scatter-add the batched engine avoids
    outs["ecn_cnt"] = jnp.zeros(n_qps, jnp.int32).at[batch["qpn"]].add(
        outs["ecn_echo"].astype(jnp.int32), mode="drop")
    return tables, RxResult(**{k: outs[k] for k in RxResult._fields})


# ---------------------------------------------------------------------------
# Batched multi-QP engine
# ---------------------------------------------------------------------------

_OUT_KEYS = ("accept", "dup", "ooo", "dropped_credit", "rkey_err",
             "dma_addr", "dma_len", "ack_psn", "ack_qpn", "send_ack",
             "send_nak", "sack", "ecn_echo")
_OUT_BOOL = ("accept", "dup", "ooo", "dropped_credit", "rkey_err",
             "send_ack", "send_nak", "ecn_echo")


@partial(jax.jit, donate_argnums=(0,))
def rx_pipeline_batched(tables: RxTables, batch: Dict[str, jax.Array]
                        ) -> Tuple[RxTables, RxResult]:
    """Batched multi-QP RX engine (the tentpole: paper §4.1 at scale).

    Grouping (all in-graph, one jitted step):
      1. one stable sort by QP — per-QP arrival order (what the PSN FSM
         sequences over) becomes contiguous segments; segment lengths
         fall out of a ``searchsorted`` over the sorted keys, segment
         ranks out of index arithmetic;
      2. each active QP gets a dense *slot*, ordered by descending
         segment length, so the QPs still alive in wave ``t`` are always
         the slot prefix ``[0, m_t)`` of width ``W = min(Q, N)``;
      3. wave ``t`` reads slot ``s``'s ``t``-th packet at sorted
         position ``seg_off[s] + t`` — a ``(W,)`` gather — and writes
         its outputs as one contiguous block at offset
         ``start[t] = sum(m_0..m_{t-1})`` in (rank, slot) layout.

    The ``while_loop`` carries per-slot state *vectors*; per wave there
    is exactly one fused ``(fields, W)`` gather, one vectorized
    ``_rx_decide`` and one ``dynamic_update_slice`` of a packed output
    matrix — no table scatters inside the loop (XLA CPU scatter is the
    thing to avoid; the engine performs a single N-sized scatter total,
    for the inverse permutation).  Lanes past ``m_t`` in the fixed-width
    block compute garbage that the next wave's write overwrites.  Trip
    count = longest per-QP segment ≈ N/Q for even traffic, not the
    batch size.  State is scattered back to the QP tables once, at the
    end.

    Bit-identical to ``rx_pipeline`` on valid lanes (per-QP state is
    independent, so cross-QP reordering cannot change any decision);
    invalid (padding) lanes yield all-zero outputs.
    """
    batch = _ensure_defaults(batch)
    n = batch["qpn"].shape[0]
    n_qps = tables.epsn.shape[0]
    w = min(n_qps, n)                       # static wave width
    valid = batch["valid"] > 0
    key = jnp.where(valid, batch["qpn"], n_qps)   # invalid -> sentinel group
    idx = jnp.arange(n, dtype=jnp.int32)

    # one stable sort by QP; pack (key, lane) into a single int32 when it
    # fits — a value sort is several times cheaper than argsort here
    if (n_qps + 1) * n + n < 2 ** 31:
        packed = jnp.sort(key.astype(jnp.int32) * n + idx)
        sk = packed // n
        order_k = packed - sk * n
    else:
        order_k = jnp.argsort(key, stable=True)
        sk = key[order_k]
    # header fields (int32) in sorted order, padded by W so live-lane
    # wave gathers stay in bounds (dead lanes are clamped in the loop)
    fmat = jnp.stack([batch[k].astype(jnp.int32) for k in _PKT_FIELDS])
    fmat = jnp.concatenate(
        [fmat[:, order_k], jnp.zeros((len(_PKT_FIELDS), w), jnp.int32)],
        axis=1)

    # per-QP segment lengths from the sorted keys (no scatter needed)
    bounds = jnp.searchsorted(sk, jnp.arange(n_qps + 1)).astype(jnp.int32)
    counts = bounds[1:] - bounds[:-1]              # (Q,) valid pkts per QP
    seg_off_qp = bounds[:-1]                       # segment starts, sorted
    rank_sorted = idx - bounds[sk]                 # rank within segment

    # dense slots ordered by descending segment length
    slot_to_qp = jnp.argsort(-counts, stable=True)[:w]
    qp_to_slot = jnp.full(n_qps + 1, w, jnp.int32).at[slot_to_qp].set(
        jnp.arange(w, dtype=jnp.int32))
    slot_len = counts[slot_to_qp]                  # nonincreasing
    seg_off_slot = seg_off_qp[slot_to_qp]

    # wave t spans output positions [start[t], start[t] + m[t]) where
    # m[t] = #slots with segment length > t (a slot prefix)
    n_waves = slot_len[0] if w else jnp.int32(0)
    m_arr = jnp.searchsorted(-slot_len, -jnp.arange(n + 1), side="left"
                             ).astype(jnp.int32)
    start_arr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(m_arr).astype(jnp.int32)])

    # (rank, slot) output position of every lane; invalid lanes go last
    n_valid = bounds[n_qps]
    pos_sorted = jnp.where(sk == n_qps, n_valid + rank_sorted,
                           start_arr[rank_sorted] + qp_to_slot[sk])
    # inverse permutation: original lane -> output position (packed sort
    # again, falling back to a scatter when the packing would overflow)
    if n * (n + w) + (n + w) < 2 ** 31:
        pos = jnp.sort(order_k * (n + w) + pos_sorted) % (n + w)
    else:
        pos = jnp.zeros(n, jnp.int32).at[order_k].set(pos_sorted)

    state0 = {f: getattr(tables, f)[slot_to_qp] for f in _STATE_FIELDS}
    outs0 = jnp.zeros((len(_OUT_KEYS), n + w), jnp.int32)
    lanes = jnp.arange(w, dtype=jnp.int32)

    def cond(carry):
        return carry[0] < n_waves

    def body(carry):
        t, state, outs = carry
        # slot s -> its t-th packet; dead slots (t >= slot_len[s]) would
        # index past their segment, so clamp explicitly — their lanes are
        # masked out of the state update below and their output columns
        # are overwritten by later waves
        lane_idx = jnp.minimum(seg_off_slot + t, n + w - 1)
        block = fmat[:, lane_idx]
        p = {k: block[i] for i, k in enumerate(_PKT_FIELDS)}
        new_state, out = _rx_decide(state, p)
        live = lanes < m_arr[t]
        state = {f: jnp.where(live, new_state[f], state[f])
                 for f in _STATE_FIELDS}
        outs = jax.lax.dynamic_update_slice(
            outs, jnp.stack([out[k].astype(jnp.int32) for k in _OUT_KEYS]),
            (0, start_arr[t]))
        return t + 1, state, outs

    _, state, outs = jax.lax.while_loop(
        cond, body, (jnp.int32(0), state0, outs0))

    tables = RxTables(**{
        f: getattr(tables, f).at[slot_to_qp].set(state[f])
        for f in _STATE_FIELDS})
    unsorted = jnp.where(valid, outs[:, pos], 0)   # fused unsort gather
    res = {}
    for i, k in enumerate(_OUT_KEYS):
        res[k] = unsorted[i] > 0 if k in _OUT_BOOL else unsorted[i]
    # per-QP CE tally as a segmented reduction over the sorted (wave)
    # layout: the CE echo is stateless, so it reads straight off the
    # sorted header columns — one cumsum + a (Q,)-gather, no scatter
    ecn_s = fmat[_PKT_FIELDS.index("ecn"), :n]
    opc_s = fmat[_PKT_FIELDS.index("opcode"), :n]
    flag = ((ecn_s > 0) & (sk < n_qps) &
            jnp.isin(opc_s, jnp.asarray(pk.PAYLOAD_OPS, jnp.int32)))
    csum = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(flag.astype(jnp.int32))])
    res["ecn_cnt"] = (csum[bounds[1:]] - csum[bounds[:-1]]).astype(jnp.int32)
    return tables, RxResult(**{k: res[k] for k in RxResult._fields})


class TxTables(NamedTuple):
    npsn: jax.Array        # (Q,) int32
    msn: jax.Array         # (Q,) int32


@partial(jax.jit, donate_argnums=(0,))
def tx_pipeline(tables: TxTables, cmds: Dict[str, jax.Array]
                ) -> Tuple[TxTables, Dict[str, jax.Array]]:
    """TX path oracle: assign consecutive PSNs per command (one command
    = one message of n_pkts fragments) and bump nPSN/MSN (§4.1 TX)."""
    def body(t, i):
        qpn = cmds["qpn"][i]
        n_pkts = cmds["n_pkts"][i]
        start = t.npsn[qpn]
        t = TxTables(
            npsn=t.npsn.at[qpn].set((start + n_pkts) & pk.PSN_MASK),
            msn=t.msn.at[qpn].add(1),
        )
        return t, {"start_psn": start}

    n = cmds["qpn"].shape[0]
    tables, outs = jax.lax.scan(body, tables, jnp.arange(n))
    return tables, outs


@partial(jax.jit, donate_argnums=(0,))
def tx_pipeline_batched(tables: TxTables, cmds: Dict[str, jax.Array]
                        ) -> Tuple[TxTables, Dict[str, jax.Array]]:
    """Batched TX engine: PSN-range assignment is a per-QP segmented
    exclusive cumulative sum — no sequential scan at all.  Bit-identical
    to ``tx_pipeline`` (same mod-2^24 arithmetic, per-QP independence).
    """
    qpn = cmds["qpn"]
    n_pkts = cmds["n_pkts"].astype(jnp.int32)
    n = qpn.shape[0]
    order = jnp.argsort(qpn, stable=True)
    sq = qpn[order]
    sn = n_pkts[order]
    excl = jnp.cumsum(sn) - sn                    # exclusive prefix sum
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sq[1:] != sq[:-1]])
    # exclusive sum at each segment start, broadcast down the segment
    # (excl is nondecreasing, so a running max of the start values works)
    seg_base = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, excl, 0))
    start_sorted = (tables.npsn[sq] + (excl - seg_base)) & pk.PSN_MASK
    start_psn = jnp.zeros(n, jnp.int32).at[order].set(
        start_sorted.astype(jnp.int32))
    tables = TxTables(
        npsn=(tables.npsn.at[qpn].add(n_pkts)) & pk.PSN_MASK,
        msn=tables.msn.at[qpn].add(1),
    )
    return tables, {"start_psn": start_psn}


RX_ENGINES = {"scan": rx_pipeline, "batched": rx_pipeline_batched}
TX_ENGINES = {"scan": tx_pipeline, "batched": tx_pipeline_batched}


def clone_tables(t):
    """Deep-copy an Rx/TxTables value onto fresh device buffers.

    Every engine donates its carried-table argument (alloc-free carry
    for the fused epoch core), so the caller's input buffers are DEAD
    after the call.  The normal ``self.tables, res = engine(self.tables,
    batch)`` rebind never notices — but any caller that feeds the same
    table value to two engines (the scan/batched bit-identity tests) or
    re-times one call in a loop (the fig benches) must clone per use."""
    return type(t)(*(jnp.array(a) for a in t))


def make_rx_tables(n_qps: int, initial_credits: int = 64) -> RxTables:
    return RxTables(
        epsn=jnp.zeros(n_qps, jnp.int32),
        msn=jnp.zeros(n_qps, jnp.int32),
        bytes_left=jnp.zeros(n_qps, jnp.int32),
        cur_vaddr=jnp.zeros(n_qps, jnp.int32),
        credits=jnp.full((n_qps,), initial_credits, jnp.int32),
        rkey=jnp.zeros(n_qps, jnp.int32),
        rxbit=jnp.zeros(n_qps, jnp.int32),
        sr=jnp.zeros(n_qps, jnp.int32),
        acc_cnt=jnp.zeros(n_qps, jnp.int32),
        dup_cnt=jnp.zeros(n_qps, jnp.int32),
        ooo_cnt=jnp.zeros(n_qps, jnp.int32),
        cdrop_cnt=jnp.zeros(n_qps, jnp.int32),
        ecn_tot=jnp.zeros(n_qps, jnp.int32),
    )


def make_tx_tables(n_qps: int) -> TxTables:
    return TxTables(npsn=jnp.zeros(n_qps, jnp.int32),
                    msn=jnp.zeros(n_qps, jnp.int32))
