"""Vectorized RoCE v2 packet-processing pipeline (paper §4.1, Fig. 2).

The FPGA realizes one deep pipeline processing one beat per cycle; the
TPU-idiomatic dual processes a *batch* of packets per invocation with
``jax.lax.scan`` carrying the per-QP state tables (PSN order within a QP
is inherently sequential, so the scan is the honest formulation — the
SIMD width lives in the table lookups and payload operations, which are
fully vectorized downstream in the service chain).

RX path:  strip/inspect headers -> PSN check against the state table ->
          accept (emit DMA command, bump ePSN/MSN) | drop-duplicate
          (re-ACK) | drop-out-of-order (NAK, triggers remote retransmit)
          -> credit check (§4.3) may still drop an otherwise valid packet.
TX path:  commands + MSN/state tables -> BTH/RETH forming -> PSN assign.

Both paths are jittable and differentiable-free integer programs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import packet as pk


class RxTables(NamedTuple):
    """The jax-side mirror of QPTables fields the RX pipeline mutates."""
    epsn: jax.Array        # (Q,) int32
    msn: jax.Array         # (Q,) int32
    bytes_left: jax.Array  # (Q,) int64
    cur_vaddr: jax.Array   # (Q,) int64
    credits: jax.Array     # (Q,) int32   downstream capacity (§4.3)


class RxResult(NamedTuple):
    accept: jax.Array      # (N,) bool   payload forwarded to DMA
    dup: jax.Array         # (N,) bool   duplicate (re-ACK, no DMA)
    ooo: jax.Array         # (N,) bool   out-of-order (NAK)
    dropped_credit: jax.Array  # (N,) bool dropped for lack of credits
    dma_addr: jax.Array    # (N,) int64  target address for accepted payloads
    dma_len: jax.Array     # (N,) int32
    ack_psn: jax.Array     # (N,) int32  cumulative ack to send back
    ack_qpn: jax.Array     # (N,) int32
    send_ack: jax.Array    # (N,) bool
    send_nak: jax.Array    # (N,) bool


def _rx_one(tables: RxTables, p) -> Tuple[RxTables, Dict]:
    """Process one packet against the tables (scan body)."""
    qpn = p["qpn"]
    opcode = p["opcode"]
    psn = p["psn"]
    plen = p["plen"].astype(jnp.int32)
    epsn = tables.epsn[qpn]
    credits = tables.credits[qpn]

    is_payload = jnp.isin(opcode, jnp.asarray(pk.PAYLOAD_OPS, jnp.int32))
    has_reth = jnp.isin(opcode, jnp.asarray(pk.RETH_OPS, jnp.int32))
    is_last = jnp.isin(opcode, jnp.asarray(
        (pk.WRITE_LAST, pk.WRITE_ONLY, pk.READ_RESP_LAST, pk.READ_RESP_ONLY),
        jnp.int32))

    in_seq = psn == epsn
    dup = (psn - epsn) % (pk.PSN_MASK + 1) > (pk.PSN_MASK // 2)  # behind ePSN
    ooo = ~in_seq & ~dup
    has_credit = credits > 0

    accept = is_payload & in_seq & has_credit & (p["valid"] > 0)
    dropped_credit = is_payload & in_seq & ~has_credit & (p["valid"] > 0)

    # DMA command formation (RETH starts a region; MIDDLE/LAST continue it)
    start_addr = jnp.where(has_reth, p["vaddr"], tables.cur_vaddr[qpn])
    dma_addr = start_addr
    new_cur = jnp.where(accept, start_addr + plen, tables.cur_vaddr[qpn])
    new_bytes = jnp.where(
        has_reth & accept, p["dma_len"].astype(jnp.int32) - plen,
        jnp.where(accept, tables.bytes_left[qpn] - plen,
                  tables.bytes_left[qpn]))
    new_epsn = jnp.where(accept, (epsn + 1) & pk.PSN_MASK, epsn)
    new_msn = jnp.where(accept & is_last, tables.msn[qpn] + 1,
                        tables.msn[qpn])
    new_credits = jnp.where(accept, credits - 1, credits)

    tables = RxTables(
        epsn=tables.epsn.at[qpn].set(new_epsn.astype(jnp.int32)),
        msn=tables.msn.at[qpn].set(new_msn.astype(jnp.int32)),
        bytes_left=tables.bytes_left.at[qpn].set(new_bytes),
        cur_vaddr=tables.cur_vaddr.at[qpn].set(new_cur),
        credits=tables.credits.at[qpn].set(new_credits.astype(jnp.int32)),
    )
    out = {
        "accept": accept, "dup": dup & is_payload, "ooo": ooo & is_payload,
        "dropped_credit": dropped_credit,
        "dma_addr": dma_addr.astype(jnp.int32),
        "dma_len": plen.astype(jnp.int32),
        "ack_psn": jnp.where(accept, psn, (new_epsn - 1) & pk.PSN_MASK
                             ).astype(jnp.int32),
        "ack_qpn": qpn.astype(jnp.int32),
        # ACK policy: ack accepted last/ack_req packets and duplicates
        "send_ack": (accept & (is_last | (p["ack_req"] > 0))) |
                    (dup & is_payload),
        "send_nak": ooo & is_payload,
    }
    return tables, out


@jax.jit
def rx_pipeline(tables: RxTables, batch: Dict[str, jax.Array]
                ) -> Tuple[RxTables, RxResult]:
    """Run the RX header pipeline over a packet batch (in arrival order)."""
    def body(t, i):
        p = {k: batch[k][i] for k in
             ("qpn", "opcode", "psn", "plen", "vaddr", "dma_len", "ack_req",
              "valid")}
        t, out = _rx_one(t, p)
        return t, out

    n = batch["qpn"].shape[0]
    tables, outs = jax.lax.scan(body, tables, jnp.arange(n))
    return tables, RxResult(**{k: outs[k] for k in RxResult._fields})


class TxTables(NamedTuple):
    npsn: jax.Array        # (Q,) int32
    msn: jax.Array         # (Q,) int32


@jax.jit
def tx_pipeline(tables: TxTables, cmds: Dict[str, jax.Array]
                ) -> Tuple[TxTables, Dict[str, jax.Array]]:
    """TX path: assign consecutive PSNs per command (one command = one
    message of n_pkts fragments) and bump nPSN/MSN (paper §4.1 TX)."""
    def body(t, i):
        qpn = cmds["qpn"][i]
        n_pkts = cmds["n_pkts"][i]
        start = t.npsn[qpn]
        t = TxTables(
            npsn=t.npsn.at[qpn].set((start + n_pkts) & pk.PSN_MASK),
            msn=t.msn.at[qpn].add(1),
        )
        return t, {"start_psn": start}

    n = cmds["qpn"].shape[0]
    tables, outs = jax.lax.scan(body, tables, jnp.arange(n))
    return tables, outs


def make_rx_tables(n_qps: int, initial_credits: int = 64) -> RxTables:
    return RxTables(
        epsn=jnp.zeros(n_qps, jnp.int32),
        msn=jnp.zeros(n_qps, jnp.int32),
        bytes_left=jnp.zeros(n_qps, jnp.int32),
        cur_vaddr=jnp.zeros(n_qps, jnp.int32),
        credits=jnp.full((n_qps,), initial_credits, jnp.int32),
    )


def make_tx_tables(n_qps: int) -> TxTables:
    return TxTables(npsn=jnp.zeros(n_qps, jnp.int32),
                    msn=jnp.zeros(n_qps, jnp.int32))
