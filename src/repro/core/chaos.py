"""Deterministic keyed-hash randomness ("chaos mode") shared by the
Python netsim and the fused epoch core.

The per-tick simulators draw loss / ECN-mark / reorder decisions from a
``numpy`` Generator whose consumption order is inherently sequential —
impossible to reproduce inside a jitted, vectorized epoch.  Chaos mode
replaces the stream with a *counter-keyed* hash: every decision is a
pure function of ``(stream seed, purpose tag, tick, event index)``,
where the event index is the decision's rank within its tick (send
order on a wire, pop order at an egress queue).  Ranks are computable
both by the sequential Python fabric (a per-tick counter) and by the
vectorized fused core (a segment rank), so the two produce identical
decision streams — which is what lets the property suite assert
bit-identical epochs under loss/ECN/reorder schedules.

Probabilities are compared in *integers*: a threshold is precomputed
once on the host as ``floor(p * 2**32)`` and the uniform 32-bit hash is
compared with ``h < threshold``.  No float ever enters the decision, so
numpy-f64 vs jax-f32 rounding can never diverge the two sides.

Purpose tags (per stream):
  1 = wire loss        2 = jitter delay      3 = reorder hit
  4 = reorder extra delay                    (fabric RED uses tag 2)
"""
from __future__ import annotations

import numpy as np

M32 = 0xFFFFFFFF

TAG_LOSS = 1
TAG_RED = 2
TAG_JITTER = 2
TAG_REORDER = 3
TAG_RDELAY = 4


def hash32(seed: int, tag: int, tick: int, idx: int) -> int:
    """SplitMix-style 32-bit finalizer over the decision key.  Pure
    integer arithmetic; the jax twin (``hash32_jnp``) is bit-equal."""
    x = (seed ^ (tag * 0x9E3779B1) ^ (tick * 0x85EBCA77)
         ^ (idx * 0xC2B2AE3D)) & M32
    x ^= x >> 16
    x = (x * 0x7FEB352D) & M32
    x ^= x >> 15
    x = (x * 0x846CA68B) & M32
    x ^= x >> 16
    return x


def hash32_jnp(seed, tag, tick, idx):
    """jax twin of ``hash32``: identical mixing on uint32 lanes."""
    import jax.numpy as jnp
    u = jnp.uint32
    x = (u(seed) ^ (u(tag) * u(0x9E3779B1)) ^
         (jnp.asarray(tick).astype(jnp.uint32) * u(0x85EBCA77)) ^
         (jnp.asarray(idx).astype(jnp.uint32) * u(0xC2B2AE3D)))
    x = x ^ (x >> u(16))
    x = x * u(0x7FEB352D)
    x = x ^ (x >> u(15))
    x = x * u(0x846CA68B)
    x = x ^ (x >> u(16))
    return x


def u32_prob(p: float) -> int:
    """Probability -> integer threshold (decision: ``hash < thresh``).
    The one place a float is touched, on the host, once per config."""
    return min(max(int(float(p) * 4294967296.0), 0), M32)


def link_stream(base_seed: int, a: int, b: int) -> int:
    """Per-directed-link stream seed (mirrors the rng seed derivation
    of ``netsim.Network``)."""
    return (base_seed * 1000 + a * 37 + b) & M32


def red_thresholds(kmin: int, kmax: int, pmax: float,
                   max_depth: int) -> np.ndarray:
    """Integer RED ramp: ``thresh[d]`` is the mark threshold for a
    dequeue leaving depth ``d``.  Saturated (>= kmax) depths get the
    always-mark threshold; at/below kmin the never-mark 0."""
    d = np.arange(max_depth + 1, dtype=np.int64)
    ramp = pmax * (d - kmin) / max(kmax - kmin, 1)
    t = np.array([u32_prob(p) for p in ramp], np.int64)
    t = np.where(d >= kmax, M32 + 1, np.where(d <= kmin, 0, t))
    return t.astype(np.int64)


def red_mark(seed: int, tick: int, idx: int, depth: int,
             kmin: int, kmax: int, pmax: float) -> bool:
    """Chaos-mode RED decision (Python fabric side).  ``idx`` is the
    pop's rank within its tick — every pop consumes one rank whether or
    not the depth lands in the ramp, so the vectorized side can rank
    pops without tracking which ones actually drew."""
    if kmax <= 0:
        return False
    if depth >= kmax:
        return True
    if depth <= kmin:
        return False
    thresh = u32_prob(pmax * (depth - kmin) / max(kmax - kmin, 1))
    return hash32(seed, TAG_RED, tick, idx) < thresh
