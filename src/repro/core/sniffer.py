"""Traffic sniffer (paper §4.7): capture traversing packets into a
standard PCAP file for analysis with Wireshark-class tools.

Mirrors the paper's design: a header-selecting filter at the link level
(e.g. capture only RoCE v2), optional payload omission to cut the
instrumentation footprint, and full bidirectional RX/TX capture that
never perturbs the datapath (we only copy header fields + optionally the
payload).  Packets are synthesized into Ethernet/IPv4/UDP/IB-BTH wire
format so standard dissectors decode them.

FPGA -> TPU design dual: the FPGA taps the MAC at line rate into a
DMA ring; here capture is a host-side observer on RdmaNode TX/RX (the
simulator's tick clock stands in for hardware timestamps), emitting the
same PCAP byte format.
"""
from __future__ import annotations

import struct
from typing import List, Optional

from repro.core import packet as pk

_PCAP_GLOBAL = struct.pack("<IHHiIII", 0xA1B2C3D4, 2, 4, 0, 0, 65535, 1)
_ETH_IPV4 = b"\x08\x00"


def _ipv4(addr: int) -> bytes:
    return struct.pack(">I", addr & 0xFFFFFFFF)


class TrafficSniffer:
    def __init__(self, *, capture_payload: bool = True,
                 protocol_filter: Optional[str] = "rocev2",
                 tick_ns: int = 1000):
        self.capture_payload = capture_payload
        self.protocol_filter = protocol_filter
        self.tick_ns = tick_ns
        self.records: List[bytes] = []
        self.n_rx = 0
        self.n_tx = 0

    def capture(self, p: pk.Packet, now_ticks: int, direction: str = "rx"):
        if self.protocol_filter == "rocev2" and p.dst_port != pk.UDP_DPORT_ROCE:
            return
        if direction == "rx":
            self.n_rx += 1
        else:
            self.n_tx += 1
        payload = b""
        if self.capture_payload and p.payload is not None:
            payload = p.payload.tobytes()
        # --- InfiniBand BTH (12 bytes) + RETH (16) when present ----------
        bth = struct.pack(">BBHI I",
                          p.opcode & 0xFF, 0, 0xFFFF,
                          p.qpn & 0x00FFFFFF,
                          ((1 if p.ack_req else 0) << 31)
                          | (p.psn & pk.PSN_MASK))
        ib = bth
        if p.opcode in pk.RETH_OPS:
            ib += struct.pack(">QII", p.vaddr, p.rkey, p.dma_len)
        ib += payload + struct.pack(">I", p.icrc & 0xFFFFFFFF)
        # --- UDP ----------------------------------------------------------
        udp_len = 8 + len(ib)
        udp = struct.pack(">HHHH", p.src_port or 0xC000, p.dst_port,
                          udp_len, 0) + ib
        # --- IPv4 ----------------------------------------------------------
        total = 20 + udp_len
        ip = struct.pack(">BBHHHBBH", 0x45, 0, total, 0, 0, 64, 17, 0) \
            + _ipv4(p.src_ip) + _ipv4(p.dst_ip) + udp
        # --- Ethernet -------------------------------------------------------
        eth = b"\x02" * 6 + b"\x04" * 6 + _ETH_IPV4 + ip
        ts_ns = now_ticks * self.tick_ns
        hdr = struct.pack("<IIII", ts_ns // 1_000_000_000,
                          (ts_ns % 1_000_000_000) // 1000,
                          len(eth), len(eth))
        self.records.append(hdr + eth)

    def write_pcap(self, path: str) -> int:
        with open(path, "wb") as f:
            f.write(_PCAP_GLOBAL)
            for r in self.records:
                f.write(r)
        return len(self.records)
