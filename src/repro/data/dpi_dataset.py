"""Synthetic DPI training data (paper §5.1.2 trains on 'common big data
payloads such as CSVs, PNGs, and TXTs versus compiled malware
executables').  We synthesize both classes with the byte-level statistics
that distinguish them: text/CSV (printable ASCII, delimiters), PNG-ish
(magic + filtered-scanline bytes), vs. ELF executables (magic, section
structure, instruction-like byte patterns, high entropy blocks)."""
from __future__ import annotations

from typing import Tuple

import numpy as np

_ELF_MAGIC = np.frombuffer(b"\x7fELF\x02\x01\x01\x00", np.uint8)
_PNG_MAGIC = np.frombuffer(b"\x89PNG\r\n\x1a\n", np.uint8)


def benign_beats(n: int, rng) -> np.ndarray:
    """64-byte beats of text / CSV / PNG-like payloads."""
    kinds = rng.integers(0, 3, n)
    out = np.zeros((n, 64), np.uint8)
    # text: printable ascii, spaces, newlines
    text = rng.choice(np.frombuffer(
        b"etaoinshrdlucmfwypvbgkjqxz ETAOIN,.;:\n 0123456789", np.uint8),
        size=(n, 64))
    # csv: digits + commas
    csv = rng.choice(np.frombuffer(b"0123456789,.-\n", np.uint8),
                     size=(n, 64))
    # png-ish: magic + low-entropy filtered bytes
    png = (rng.integers(0, 64, (n, 64))).astype(np.uint8)
    png[:, :8] = _PNG_MAGIC
    out[kinds == 0] = text[kinds == 0]
    out[kinds == 1] = csv[kinds == 1]
    out[kinds == 2] = png[kinds == 2]
    return out


def malicious_beats(n: int, rng) -> np.ndarray:
    """64-byte beats of executable-like payloads: x86-ish opcode mix,
    high-entropy packed sections, ELF header fragments."""
    out = rng.integers(0, 256, (n, 64), dtype=np.uint8)
    # sprinkle common x86-64 opcodes / prologue patterns
    ops = np.frombuffer(b"\x55\x48\x89\xe5\x48\x83\xec\x00\xc3\x90\xe8\x0f"
                        b"\x44\x24\x8b\x45", np.uint8)
    idx = rng.integers(0, 64, (n, 24))
    out[np.arange(n)[:, None], idx] = rng.choice(ops, (n, 24))
    hdr = rng.random(n) < 0.2
    out[hdr, :8] = _ELF_MAGIC
    return out


def make_dataset(n_per_class: int = 4096, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    x = np.concatenate([benign_beats(n_per_class, rng),
                        malicious_beats(n_per_class, rng)])
    y = np.concatenate([np.zeros(n_per_class), np.ones(n_per_class)])
    perm = rng.permutation(len(x))
    return x[perm], y[perm].astype(np.float32)


def payload_with_embedded_malware(mtu: int, frac: float, rng) -> np.ndarray:
    """One packet payload, ``frac`` of its beats malicious (for the
    partial-embedding detection-rate experiment, paper: 89.35%)."""
    beats = mtu // 64
    n_mal = int(round(frac * beats))
    b = benign_beats(beats, rng)
    if n_mal:
        m = malicious_beats(n_mal, rng)
        pos = rng.choice(beats, n_mal, replace=False)
        b[pos] = m
    return b.reshape(mtu)
