"""Synthetic data: LM token shards and DLRM records, with byte-level
shard encodings so the same data can travel the BALBOA RDMA path
(disaggregated storage -> service chain -> device)."""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# LM token streams (Zipfian, with enough structure that loss decreases)
# ---------------------------------------------------------------------------

def lm_shard(index: int, batch: int, seq: int, vocab: int,
             seed: int = 1234) -> Dict[str, np.ndarray]:
    """Deterministic (index, seed) -> {tokens, targets}.  A simple
    k-gram Markov stream: next token = (a * prev + c) % vocab with
    Zipf-ish noise — learnable structure for the e2e examples."""
    rng = np.random.default_rng(seed + index)
    a = 31 * (seed % 7 + 1)        # one consistent rule per stream
    toks = np.zeros((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    noise = (rng.random((batch, seq)) < 0.15)
    rand = rng.integers(0, vocab, (batch, seq)).astype(np.int32)
    for t in range(seq):
        nxt = (a * toks[:, t] + 7) % vocab
        toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def encode_lm_shard(batch: Dict[str, np.ndarray]) -> np.ndarray:
    """Pack an LM batch into bytes for RDMA transport."""
    b, s = batch["tokens"].shape
    header = np.array([0x4C4D, b, s], np.int32)     # 'LM'
    body = np.concatenate([batch["tokens"].reshape(-1),
                           batch["targets"].reshape(-1)]).astype(np.int32)
    return np.concatenate([header, body]).view(np.uint8)


def decode_lm_shard(raw: np.ndarray) -> Dict[str, np.ndarray]:
    words = np.frombuffer(raw.tobytes(), np.int32)
    assert words[0] == 0x4C4D, "bad LM shard magic"
    b, s = int(words[1]), int(words[2])
    body = words[3:3 + 2 * b * s]
    return {"tokens": body[:b * s].reshape(b, s).copy(),
            "targets": body[b * s:].reshape(b, s).copy()}


# ---------------------------------------------------------------------------
# DLRM records (paper §8: dense + sparse features per record)
# ---------------------------------------------------------------------------

def dlrm_shard(index: int, n_records: int, n_dense: int = 13,
               n_sparse: int = 26, seed: int = 99) -> np.ndarray:
    """Raw (UNpreprocessed) records as int32: dense features may be
    negative / large (need Neg2Zero + Log), sparse ids exceed the table
    range (need Modulus).  Label = f(features) baked into record 0's low
    bit via a synthetic rule (decoded after preprocessing)."""
    rng = np.random.default_rng(seed + index)
    dense = rng.integers(-100, 100_000, (n_records, n_dense)).astype(np.int32)
    sparse = rng.integers(0, 1 << 30, (n_records, n_sparse)).astype(np.int32)
    return np.concatenate([dense, sparse], axis=1)


def dlrm_labels(recs: np.ndarray, n_dense: int, modulus: int) -> np.ndarray:
    """Synthetic ground truth: click iff a hash of the true (post-
    preprocessing) features crosses a threshold — learnable."""
    dense = np.log1p(np.maximum(recs[:, :n_dense].astype(np.float64), 0))
    sparse = recs[:, n_dense:] % modulus
    score = dense.sum(1) / n_dense + (sparse % 7).mean(1)
    return (score > np.median(score)).astype(np.float32)


def encode_dlrm_shard(recs: np.ndarray) -> np.ndarray:
    n, w = recs.shape
    header = np.array([0x444C, n, w], np.int32)     # 'DL'
    return np.concatenate([header, recs.reshape(-1)]).view(np.uint8)


def decode_dlrm_shard(raw: np.ndarray) -> Dict[str, np.ndarray]:
    words = np.frombuffer(raw.tobytes(), np.int32)
    assert words[0] == 0x444C, "bad DLRM shard magic"
    n, w = int(words[1]), int(words[2])
    recs = words[3:3 + n * w].reshape(n, w).copy()
    return {"records": recs}


def encode_dlrm_packets(recs: np.ndarray, mtu: int = 4096) -> np.ndarray:
    """Pack records into an MTU-ALIGNED packet stream: each packet
    carries as many whole records as fit (``(mtu//4) // record_words``),
    zero-padded to the packet boundary.  This is the record-aligned
    layout the streaming ingest stripes across QPs — no record ever
    straddles a packet (or stripe) boundary, so per-packet services and
    per-tile kernels rewrite whole records only.  The inverse transform
    is device-side: ``repro.core.ingest.make_dlrm_tile_decoder``."""
    n, w = recs.shape
    words = mtu // 4
    rpp = words // w                  # records per packet
    n_pkts = -(-n // rpp)
    buf = np.zeros((n_pkts, words), np.int32)
    for p in range(n_pkts):
        chunk = recs[p * rpp:(p + 1) * rpp]
        buf[p, :chunk.size] = chunk.reshape(-1)
    return buf.reshape(-1).view(np.uint8)


def decode_preprocessed_dlrm(raw: np.ndarray, n_dense: int
                             ) -> Dict[str, np.ndarray]:
    """Decode a shard whose record payload already passed the on-path
    preprocessing service (dense words are float32 bit patterns)."""
    d = decode_dlrm_shard(raw)
    recs = d["records"]
    dense = recs[:, :n_dense].view(np.float32)
    sparse = recs[:, n_dense:]
    return {"dense": dense.copy(), "sparse": sparse.copy()}
