"""Train / serve step builders — the functions the launcher jits/lowers."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, TrainConfig
from repro.models.model import Model
from repro.optim.optimizers import (clip_by_global_norm, compress_grads_bf16,
                                    cosine_schedule, make_optimizer)

GATE_BIAS_LR = 0.001      # DeepSeek-V3 aux-loss-free bias update rate


def _update_gate_bias(params, expert_load):
    """Aux-loss-free load balancing (V3): nudge every router gate bias
    against the measured violation sign."""
    mean = jnp.mean(expert_load)
    delta = GATE_BIAS_LR * jnp.sign(mean - expert_load)

    def fix(path, x):
        if path and getattr(path[-1], "key", None) == "gate_bias":
            return x + delta.astype(x.dtype)
        return x
    return jax.tree_util.tree_map_with_path(fix, params)


def make_train_step(model: Model, tc: TrainConfig,
                    total_steps: Optional[int] = None) -> Callable:
    cfg = model.cfg
    opt = make_optimizer(cfg.optimizer, tc.weight_decay)
    schedule = cosine_schedule(tc.learning_rate, tc.warmup_steps,
                               total_steps or tc.steps)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        if tc.microbatches > 1:
            # gradient accumulation: split the global batch along its
            # batch dim (mrope_pos carries batch on axis 1)
            full_b = batch["tokens"].shape[0]
            size = full_b // tc.microbatches

            def slice_mb(i):
                def sl(x):
                    if x.ndim >= 1 and x.shape[0] == full_b:
                        return jax.lax.dynamic_slice_in_dim(
                            x, i * size, size, 0)
                    if x.ndim >= 2 and x.shape[1] == full_b:
                        return jax.lax.dynamic_slice_in_dim(
                            x, i * size, size, 1)
                    return x
                return jax.tree.map(sl, batch)

            def grad_of(mb):
                return jax.value_and_grad(
                    lambda p: model.loss(p, mb), has_aux=True)(params)

            (loss0, metrics), g0 = grad_of(slice_mb(0))

            def micro(i, carry):
                gsum, lsum, msum = carry
                (l, m), g = grad_of(slice_mb(i))
                return (jax.tree.map(jnp.add, gsum, g), lsum + l,
                        jax.tree.map(jnp.add, msum, m))

            grads, loss, metrics = jax.lax.fori_loop(
                1, tc.microbatches, micro, (g0, loss0, metrics))
            inv = 1.0 / tc.microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            metrics = jax.tree.map(lambda m: m * inv, metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)

        if tc.pod_grad_compression == "bf16":
            grads = compress_grads_bf16(grads)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule(step)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        if cfg.aux_free_bias:
            params = _update_gate_bias(params, metrics["expert_load"])
        out_metrics = {
            "loss": metrics["loss"], "xent": metrics["xent"],
            "aux": metrics["aux"], "grad_norm": gnorm, "lr": lr,
        }
        return params, opt_state, out_metrics

    return train_step, opt


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        logits, new_cache = model.prefill(params, batch, cache)
        return logits, new_cache
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, tokens, index):
        logits, new_cache = model.decode_step(params, cache, tokens, index)
        # greedy next token (serving returns tokens, not logits, to keep
        # the host <-> device traffic at O(batch))
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache
    return decode_step
