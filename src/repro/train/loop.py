"""Training loop with fault tolerance (checkpoint/auto-resume), the
BALBOA ingest data plane, and failure injection for tests.

This is the host-scale loop the examples run on the container's CPU
devices; the *same* step function is what the multi-pod dry-run lowers
for the production meshes — one code path, two scales.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig, TrainConfig
from repro.checkpoint.checkpoint import Checkpointer
from repro.models import params as P
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.parallel import sharding as sh
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_loss: float
    losses: list
    resumed_from: Optional[int]
    wall_s: float


class Trainer:
    """Fault-tolerant trainer: init-or-resume, checkpoint every N steps,
    survives injected crashes by restarting from the latest step."""

    def __init__(self, model: Model, tc: TrainConfig,
                 mesh=None, rules=None):
        self.model = model
        self.tc = tc
        self.mesh = mesh
        self.rules = rules or sh.make_rules("train")
        self.step_fn, self.opt = make_train_step(model, tc)
        self.ckpt = Checkpointer(tc.checkpoint_dir)
        self._jitted = jax.jit(self.step_fn, donate_argnums=(0, 1))

    def init_state(self, seed: int = 0):
        params = self.model.init_params(jax.random.key(seed))
        ospec = self.opt.state_spec(self.model.param_spec())
        opt_state = P.init(ospec, jax.random.key(seed + 1), "float32")
        return params, opt_state

    def run(self, batches: Iterator[Dict[str, np.ndarray]],
            steps: Optional[int] = None,
            crash_at: Optional[int] = None) -> TrainResult:
        """Train; if a checkpoint exists in tc.checkpoint_dir, resume.
        ``crash_at``: raise at that step (failure-injection for tests)."""
        t0 = time.time()
        steps = steps or self.tc.steps
        resumed_from = None
        params, opt_state = self.init_state(self.tc.seed)
        start = 0
        if self.ckpt.latest_step() is not None:
            like = {"params": params, "opt": opt_state}
            start, state = self.ckpt.restore(like)
            params, opt_state = state["params"], state["opt"]
            resumed_from = start
        losses = []
        ctx = sh.activate(self.mesh, self.rules) if self.mesh is not None \
            else _null_ctx()
        with ctx:
            try:
                for i, batch in enumerate(batches):
                    step = start + i
                    if step >= steps:
                        break
                    if crash_at is not None and step == crash_at:
                        raise RuntimeError(f"injected failure at step {step}")
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                    params, opt_state, metrics = self._jitted(
                        params, opt_state, batch, jnp.asarray(step, jnp.int32))
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    if step % self.tc.log_every == 0:
                        print(f"[train] step {step} loss {loss:.4f} "
                              f"lr {float(metrics['lr']):.2e} "
                              f"gnorm {float(metrics['grad_norm']):.3f}",
                              flush=True)
                    if (step + 1) % self.tc.checkpoint_every == 0:
                        self.ckpt.save(step + 1,
                                       {"params": params, "opt": opt_state})
            finally:
                # crash consistency: an async save started before a crash
                # must be durable before the failure propagates, or the
                # resume path would silently restart from an older step
                self.ckpt.wait()
        return TrainResult(len(losses), losses[-1] if losses else float("nan"),
                           losses, resumed_from, time.time() - t0)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def lm_batch_iterator(cfg: ModelConfig, batch: int, seq: int,
                      n: int = 10**9, seed: int = 0):
    from repro.data.synthetic import lm_shard
    i = 0
    while i < n:
        yield lm_shard(i, batch, seq, cfg.vocab, seed=seed)
        i += 1
