"""Configuration dataclasses shared across the framework.

``ModelConfig`` is the single source of truth for an architecture; the
per-arch files in ``repro.configs`` instantiate it with the exact values
from the assignment sheet.  ``ShapeConfig`` describes one (seq_len,
global_batch, kind) input-shape cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# Layer kinds usable in ``ModelConfig.pattern``.
LAYER_KINDS = (
    "global",   # full causal attention
    "local",    # sliding-window causal attention
    "mla",      # multi-head latent attention (DeepSeek)
    "mlstm",    # xLSTM matrix-memory block
    "slstm",    # xLSTM scalar-memory block
    "rglru",    # Griffin / RecurrentGemma gated linear recurrent unit
)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four LM shapes every assigned architecture is paired with.
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
)
SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (exact values from the assignment sheet)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # ---- layer pattern -------------------------------------------------
    # The model is built as floor(n_layers/len(pattern)) scanned blocks of
    # ``pattern`` plus an unscanned tail of pattern[:n_layers % len].
    pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 4096
    attn_softcap: float = 0.0       # 0 disables (gemma2: 50.0)
    final_softcap: float = 0.0      # 0 disables (gemma2: 30.0)

    # ---- positional ----------------------------------------------------
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3: separate theta for global layers
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)  # qwen2-vl M-RoPE (t,h,w)

    # ---- MoE -----------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim (assignment d_ff)
    first_dense_layers: int = 0
    dense_d_ff: int = 0             # hidden dim of the leading dense layers
    aux_free_bias: bool = False     # DeepSeek-V3 aux-loss-free gate bias
    router_aux_coef: float = 0.0    # GShard-style load-balance loss coef
    routed_scaling: float = 1.0

    # ---- MLA (DeepSeek) -------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- recurrent (xLSTM / Griffin) ------------------------------------
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4
    mlstm_chunk: int = 256          # chunkwise-parallel mLSTM chunk size

    # ---- encoder-decoder (Whisper) ---------------------------------------
    n_encoder_layers: int = 0
    audio_stub: bool = False        # inputs are precomputed frame embeddings
    vision_stub: bool = False       # inputs include (vision_embed, vision_mask)

    # ---- extras ----------------------------------------------------------
    mtp: bool = False               # DeepSeek-V3 multi-token-prediction head
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    use_qk_norm: bool = False       # gemma3 per-head RMSNorm on q/k
    gate_fn: str = "softmax"        # MoE router: softmax (v2) | sigmoid (v3)
    attn_impl: str = "naive"        # naive | chunked (online-softmax flash)
    attn_chunk: int = 1024          # kv chunk for attn_impl="chunked"
    ffn_act: str = "silu"           # silu | gelu
    sandwich_norm: bool = False     # gemma2/3 pre+post norm around sublayers
    norm_type: str = "rms"          # rms | ln (whisper)
    ffn_gated: bool = True          # SwiGLU/GeGLU vs plain MLP
    ffn_bias: bool = False          # whisper-style biases
    pos_embed: str = "rope"         # rope | sinusoidal (whisper)
    scale_embed: bool = False       # gemma: embeddings * sqrt(d_model)

    # ---- numerics / training policy --------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"        # adamw | adafactor
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    scan_layers: bool = True
    # ---- perf knobs (hillclimbed in EXPERIMENTS.md §Perf) -----------------
    expert_sharding: str = "ep_tp"  # ep_tp: E->data, ff->model (TP psum)
                                    # ep2d:  E->(data,model), no expert TP
    kv_cache_quant: bool = False    # int8 KV cache w/ per-slot scales

    # shapes this arch is evaluated on; names from SHAPES_BY_NAME, with
    # skips applied per DESIGN.md §Arch-applicability.
    shape_names: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    skip_shapes: Tuple[str, ...] = ()   # recorded skips (reason in DESIGN.md)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_blocks(self) -> int:
        return self.scanned_layers // len(self.pattern)

    @property
    def scanned_layers(self) -> int:
        body = self.n_layers - self.first_dense_layers
        return (body // len(self.pattern)) * len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        body = self.n_layers - self.first_dense_layers
        return self.pattern[: body % len(self.pattern)]

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def active_shapes(self) -> Tuple[ShapeConfig, ...]:
        return tuple(
            SHAPES_BY_NAME[n] for n in self.shape_names if n not in self.skip_shapes
        )

    def cell_status(self, shape_name: str) -> str:
        if shape_name in self.skip_shapes:
            return "skip"
        return "run"


@dataclass(frozen=True)
class DLRMConfig:
    """The paper's own workload (§8): DLRM behind the BALBOA service chain."""

    name: str = "dlrm"
    n_dense: int = 13               # Criteo-like dense feature count
    n_sparse: int = 26              # sparse (categorical) feature count
    embed_rows: int = 100_000       # rows per embedding table (after Modulus)
    embed_dim: int = 64
    bottom_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 256, 1)
    modulus: int = 100_000          # paper §8.1 Modulus operator range
    param_dtype: str = "float32"
    compute_dtype: str = "float32"


@dataclass
class TrainConfig:
    """Training-loop knobs (launcher-level)."""

    steps: int = 100
    microbatches: int = 1           # gradient accumulation
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    # cross-pod gradient compression: none | bf16 | topk
    pod_grad_compression: str = "none"
    topk_fraction: float = 0.05
    log_every: int = 10
