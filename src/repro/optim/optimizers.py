"""Optimizers: AdamW and Adafactor (factored second moments), plus
global-norm clipping, LR schedules and cross-pod gradient compression.

Built in-tree (no optax in this environment).  States are spec'd with
logical axes so the dry-run can shard 671B-parameter optimizer state
without allocating it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import Spec, is_spec


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(lr: float, warmup: int, total: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * lr * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01

    def state_spec(self, param_spec):
        """Spec tree (same logical axes as the params, fp32)."""
        def one(s: Spec):
            return {"m": Spec(s.shape, s.axes, "zeros", "float32"),
                    "v": Spec(s.shape, s.axes, "zeros", "float32")}
        return {"slots": jax.tree.map(one, param_spec, is_leaf=is_spec),
                "count": Spec((), (), "zeros", "int32")}

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1 - self.b1 ** c
        bc2 = 1 - self.b2 ** c

        def one(g, slot, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * slot["m"] + (1 - self.b1) * g32
            v = self.b2 * slot["v"] + (1 - self.b2) * jnp.square(g32)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, {"m": m, "v": v}

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_slots = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"slots": new_slots, "count": count}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — memory-lean for the 200B+ archs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8            # t^-decay second-moment decay exponent
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def state_spec(self, param_spec):
        def one(s: Spec):
            if len(s.shape) >= 2:
                row_shape = s.shape[:-1]
                col_shape = s.shape[:-2] + s.shape[-1:]
                return {
                    "v_row": Spec(row_shape, s.axes[:-1], "zeros", "float32"),
                    "v_col": Spec(col_shape, s.axes[:-2] + s.axes[-1:],
                                  "zeros", "float32"),
                }
            return {"v": Spec(s.shape, s.axes, "zeros", "float32")}
        return {"slots": jax.tree.map(one, param_spec, is_leaf=is_spec),
                "count": Spec((), (), "zeros", "int32")}

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta2 = 1.0 - c ** (-self.decay)

        def one(g, slot, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if "v_row" in slot:
                v_row = beta2 * slot["v_row"] + (1 - beta2) * jnp.mean(
                    g2, axis=-1)
                v_col = beta2 * slot["v_col"] + (1 - beta2) * jnp.mean(
                    g2, axis=-2)
                row_mean = jnp.mean(v_row, axis=-1, keepdims=True)
                r = v_row / jnp.maximum(row_mean, self.eps)
                upd = g32 / (jnp.sqrt(r)[..., None]
                             * jnp.sqrt(v_col)[..., None, :]
                             + self.eps)
                new_slot = {"v_row": v_row, "v_col": v_col}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                upd = g32 / (jnp.sqrt(v) + self.eps)
                new_slot = {"v": v}
            # update clipping by RMS (Adafactor's d=1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, new_slot

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_slots = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"slots": new_slots, "count": count}


def make_optimizer(name: str, weight_decay: float = 0.01):
    if name == "adamw":
        return AdamW(weight_decay=weight_decay)
    if name == "adafactor":
        return Adafactor()
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Cross-pod gradient compression (paper-adjacent: the pod axis is the
# RDMA/DCI domain BALBOA serves; compressing what crosses it is the
# distributed-optimization analogue of on-NIC stream processing).
# ---------------------------------------------------------------------------

def compress_grads_bf16(grads):
    """Quantize gradients to bf16 before the cross-pod all-reduce; XLA
    then moves 2 bytes/element across the pod axis instead of 4."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def topk_error_feedback(grads, residual, fraction: float):
    """Error-feedback top-k sparsification (per leaf).  Returns
    (sparse_grads, new_residual).  Used on the pod axis in examples and
    unit tests; magnitude top-k keeps ``fraction`` of entries."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        flat = g32.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g32) >= thresh
        sparse = jnp.where(mask, g32, 0.0)
        return sparse.astype(g.dtype), g32 - sparse
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
