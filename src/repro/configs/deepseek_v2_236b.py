"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (per expert)
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from repro.common.config import ModelConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=1536,
        vocab=102400,
        pattern=("mla",),
        # MoE
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        first_dense_layers=1,
        dense_d_ff=12288,
        gate_fn="softmax",
        router_aux_coef=0.003,
        routed_scaling=16.0,
        # MLA
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        param_dtype="bfloat16",
        optimizer="adafactor",
        skip_shapes=("long_500k",),   # full attention (MLA)
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=3, first_dense_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, vocab=512, moe_d_ff=32, d_ff=32, dense_d_ff=64,
        n_experts=8, top_k=2, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        param_dtype="float32",
    )
