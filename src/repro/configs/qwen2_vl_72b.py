"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution (vision frontend STUB:
input_specs provides patch embeddings + vision mask).
[arXiv:2409.12191; hf]"""
from repro.common.config import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab=152064,
        pattern=("global",),
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # t/h/w bands, sum = head_dim/2
        vision_stub=True,
        param_dtype="bfloat16",
        optimizer="adafactor",
        skip_shapes=("long_500k",),   # pure full attention
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, mrope_sections=(4, 2, 2),
        param_dtype="float32",
    )
