"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global, 128k context.
[hf:google/gemma-3-4b-pt; assignment sheet]"""
from repro.common.config import ModelConfig

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab=262144,
        # 5 local : 1 global; 34 = 5*6 + 4 -> tail of 4 local layers
        pattern=("local", "local", "local", "local", "local", "global"),
        sliding_window=1024,
        rope_theta=10_000.0,          # local layers
        rope_theta_global=1_000_000.0,  # global layers
        use_qk_norm=True,
        sandwich_norm=True,
        scale_embed=True,
        norm_eps=1e-6,
        optimizer="adamw",
        # hybrid local/global: long_500k RUN (see DESIGN.md §Arch-applicability)
        skip_shapes=(),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=7,                   # one pattern block + 1 tail local
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=16,
    )
