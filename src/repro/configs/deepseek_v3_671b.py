"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048 (per expert)
vocab=129280, MoE 1 shared + 256 routed top-8, MLA, aux-loss-free bias,
MTP.  [arXiv:2412.19437; hf]"""
from repro.common.config import ModelConfig

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=2048,
        vocab=129280,
        pattern=("mla",),
        # MoE
        n_experts=256,
        n_shared_experts=1,
        top_k=8,
        moe_d_ff=2048,
        first_dense_layers=3,
        dense_d_ff=18432,
        gate_fn="sigmoid",
        aux_free_bias=True,
        routed_scaling=2.5,
        # MLA
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mtp=True,
        # 671B: bf16 params + factored optimizer to fit the pod
        param_dtype="bfloat16",
        optimizer="adafactor",
        skip_shapes=("long_500k",),   # full attention (MLA)
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=3, first_dense_layers=1, d_model=64, n_heads=4,
        n_kv_heads=4, vocab=512, moe_d_ff=32, d_ff=32, dense_d_ff=64,
        n_experts=8, top_k=2, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        param_dtype="float32",
    )
