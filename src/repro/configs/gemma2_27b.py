"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000, local+global alternating, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.common.config import ModelConfig

ARCH_ID = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        pattern=("local", "global"),
        sliding_window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        rope_theta=10_000.0,
        sandwich_norm=True,
        scale_embed=True,
        optimizer="adamw",
        skip_shapes=(),               # hybrid local/global: long_500k RUN
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=16,
    )
