"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865,
encoder-decoder, conv frontend STUB (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356]

32k/500k shapes exceed Whisper's real max positions; they are exercised
structurally as assigned (DESIGN.md).  long_500k skipped (full attention).
"""
from repro.common.config import ModelConfig

ARCH_ID = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=6,                   # decoder layers
        n_encoder_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        pattern=("global",),
        norm_type="ln",
        ffn_gated=False,
        ffn_bias=True,
        ffn_act="gelu",
        pos_embed="sinusoidal",
        audio_stub=True,
        norm_eps=1e-5,
        optimizer="adamw",
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512,
    )
