"""dlrm — the paper's own workload (§8): DLRM online training behind the
BALBOA service chain (Neg2Zero -> Log on dense, Modulus on sparse),
streamed from disaggregated storage directly to accelerator memory.
[arXiv:1906.00091; paper Figs 9-11]"""
from repro.common.config import DLRMConfig

ARCH_ID = "dlrm"


def config() -> DLRMConfig:
    return DLRMConfig()


def smoke_config() -> DLRMConfig:
    return DLRMConfig(embed_rows=1000, embed_dim=16,
                      bottom_mlp=(32, 16), top_mlp=(32, 1), modulus=1000)
