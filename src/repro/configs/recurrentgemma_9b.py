"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, RG-LRU + local attention 1:2 (Griffin).
[arXiv:2402.19427]

38 = 12 * (rglru, rglru, local) + tail (rglru, rglru)."""
from repro.common.config import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        pattern=("rglru", "rglru", "local"),
        sliding_window=2048,
        lru_width=4096,
        conv_width=4,
        rope_theta=10_000.0,
        scale_embed=True,
        optimizer="adamw",
        skip_shapes=(),               # sub-quadratic: long_500k RUN
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=5,                   # one block + tail (rglru, rglru)
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, lru_width=64, sliding_window=16,
    )
