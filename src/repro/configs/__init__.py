"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs import (deepseek_v2_236b, deepseek_v3_671b, dlrm,
                           gemma2_2b, gemma2_27b, gemma3_4b, granite_3_2b,
                           qwen2_vl_72b, recurrentgemma_9b, whisper_base,
                           xlstm_125m)

_MODULES = (
    gemma3_4b, gemma2_27b, gemma2_2b, granite_3_2b, xlstm_125m,
    whisper_base, deepseek_v3_671b, deepseek_v2_236b, qwen2_vl_72b,
    recurrentgemma_9b,
)

REGISTRY: Dict[str, Callable] = {m.ARCH_ID: m.config for m in _MODULES}
SMOKE_REGISTRY: Dict[str, Callable] = {m.ARCH_ID: m.smoke_config
                                       for m in _MODULES}
ALL_ARCHS = tuple(REGISTRY)

# The paper's own workload (different config type; used by examples/benches)
DLRM_CONFIG = dlrm.config
DLRM_SMOKE = dlrm.smoke_config


def get_config(arch: str):
    if arch == "dlrm":
        return dlrm.config()
    return REGISTRY[arch]()


def get_smoke_config(arch: str):
    if arch == "dlrm":
        return dlrm.smoke_config()
    return SMOKE_REGISTRY[arch]()
