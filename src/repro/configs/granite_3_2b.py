"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155, plain GQA.  [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.common.config import ModelConfig

ARCH_ID = "granite-3-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab=49155,
        pattern=("global",),
        rope_theta=10_000.0,
        optimizer="adamw",
        # pure full attention -> long-context decode skipped (DESIGN.md)
        skip_shapes=("long_500k",),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
    )
