"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304,
sLSTM + mLSTM blocks.  [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own projections (mLSTM: pre-up-projection
factor 2; sLSTM: post-up-projection GeGLU factor 4/3).  The 125M block
ratio is not pinned in the paper — we alternate mLSTM/sLSTM 1:1 (recorded
assumption, DESIGN.md §Arch-applicability)."""
from repro.common.config import ModelConfig

ARCH_ID = "xlstm-125m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        pattern=("mlstm", "slstm"),
        conv_width=4,
        mlstm_chunk=256,
        optimizer="adamw",
        skip_shapes=(),               # sub-quadratic: long_500k RUN
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, vocab=512,
        mlstm_chunk=16,
    )
