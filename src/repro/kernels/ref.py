"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth the kernels are allclose-tested against
(tests/test_kernels.py sweeps shapes/dtypes; AES additionally checks
FIPS-197 vectors, CRC32 checks zlib).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

# ===========================================================================
# AES-128 (FIPS-197)
# ===========================================================================

SBOX = np.array([
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b,
    0xfe, 0xd7, 0xab, 0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0,
    0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26,
    0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2,
    0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0,
    0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed,
    0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f,
    0x50, 0x3c, 0x9f, 0xa8, 0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5,
    0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec,
    0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14,
    0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c,
    0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d,
    0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f,
    0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e,
    0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e, 0xe1, 0xf8, 0x98, 0x11,
    0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f,
    0xb0, 0x54, 0xbb, 0x16], np.int32)

INV_SBOX = np.zeros(256, np.int32)
INV_SBOX[SBOX] = np.arange(256)

# flat index i = r + 4c (column-major state); ShiftRows: row r rotates
# left by r columns.
_SHIFT_IDX = np.array([(i % 4) + 4 * (((i // 4) + (i % 4)) % 4)
                       for i in range(16)], np.int32)
_INV_SHIFT_IDX = np.array([(i % 4) + 4 * (((i // 4) - (i % 4)) % 4)
                           for i in range(16)], np.int32)

_RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10,
                  0x20, 0x40, 0x80, 0x1B, 0x36], np.int32)


def expand_key(key: np.ndarray) -> np.ndarray:
    """FIPS-197 key schedule: (16,) uint8 -> (11, 16) uint8 round keys."""
    key = np.asarray(key, np.uint8)
    assert key.shape == (16,)
    w = [key[4 * i:4 * i + 4].astype(np.int32) for i in range(4)]
    for i in range(4, 44):
        t = w[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = SBOX[t]
            t[0] ^= _RCON[i // 4 - 1]
        w.append(w[i - 4] ^ t)
    rk = np.stack([np.concatenate(w[4 * r:4 * r + 4]) for r in range(11)])
    return rk.astype(np.uint8)


def _xt(x):
    """GF(2^8) xtime on int32 lanes."""
    return ((x << 1) ^ jnp.where((x & 0x80) != 0, 0x1B, 0)) & 0xFF


def _mix_columns(s):
    """s: (..., 16) int32 column-major; per column [a0..a3]:
    b0 = 2a0^3a1^a2^a3 etc."""
    a = s.reshape(s.shape[:-1] + (4, 4))      # (..., c, r)
    a0, a1, a2, a3 = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    b0 = _xt(a0) ^ (_xt(a1) ^ a1) ^ a2 ^ a3
    b1 = a0 ^ _xt(a1) ^ (_xt(a2) ^ a2) ^ a3
    b2 = a0 ^ a1 ^ _xt(a2) ^ (_xt(a3) ^ a3)
    b3 = (_xt(a0) ^ a0) ^ a1 ^ a2 ^ _xt(a3)
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)


def _inv_mix_columns(s):
    a = s.reshape(s.shape[:-1] + (4, 4))
    a0, a1, a2, a3 = a[..., 0], a[..., 1], a[..., 2], a[..., 3]

    def m(x, c):
        x2 = _xt(x)
        x4 = _xt(x2)
        x8 = _xt(x4)
        out = jnp.zeros_like(x)
        if c & 8:
            out = out ^ x8
        if c & 4:
            out = out ^ x4
        if c & 2:
            out = out ^ x2
        if c & 1:
            out = out ^ x
        return out

    b0 = m(a0, 14) ^ m(a1, 11) ^ m(a2, 13) ^ m(a3, 9)
    b1 = m(a0, 9) ^ m(a1, 14) ^ m(a2, 11) ^ m(a3, 13)
    b2 = m(a0, 13) ^ m(a1, 9) ^ m(a2, 14) ^ m(a3, 11)
    b3 = m(a0, 11) ^ m(a1, 13) ^ m(a2, 9) ^ m(a3, 14)
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)


def aes_encrypt_ref(blocks: jax.Array, round_keys) -> jax.Array:
    """blocks: (N, 16) uint8; round_keys (11, 16) uint8 -> (N, 16) uint8."""
    sbox = jnp.asarray(SBOX)
    sidx = jnp.asarray(_SHIFT_IDX)
    rk = jnp.asarray(round_keys).astype(jnp.int32)
    st = blocks.astype(jnp.int32)
    st = st ^ rk[0]
    for r in range(1, 10):
        st = sbox[st]
        st = st[:, sidx]
        st = _mix_columns(st)
        st = st ^ rk[r]
    st = sbox[st]
    st = st[:, sidx]
    st = st ^ rk[10]
    return st.astype(jnp.uint8)


def aes_decrypt_ref(blocks: jax.Array, round_keys) -> jax.Array:
    inv_sbox = jnp.asarray(INV_SBOX)
    iidx = jnp.asarray(_INV_SHIFT_IDX)
    rk = jnp.asarray(round_keys).astype(jnp.int32)
    st = blocks.astype(jnp.int32)
    st = st ^ rk[10]
    for r in range(9, 0, -1):
        st = st[:, iidx]
        st = inv_sbox[st]
        st = st ^ rk[r]
        st = _inv_mix_columns(st)
    st = st[:, iidx]
    st = inv_sbox[st]
    st = st ^ rk[0]
    return st.astype(jnp.uint8)


# ===========================================================================
# CRC32 (reflected 0xEDB88320 — Ethernet/RoCE ICRC polynomial)
# ===========================================================================

def _crc_table() -> np.ndarray:
    t = np.zeros(256, np.uint32)
    for i in range(256):
        c = np.uint32(i)
        for _ in range(8):
            c = np.uint32((c >> 1) ^ (0xEDB88320 if (c & 1) else 0))
        t[i] = c
    return t

CRC_TABLE = _crc_table()

# slice-by-8 tables: T[k][b] = crc of byte b advanced by k+1 zero bytes
def _crc_tables8() -> np.ndarray:
    t = np.zeros((8, 256), np.uint32)
    t[0] = CRC_TABLE
    for k in range(1, 8):
        t[k] = (t[k - 1] >> np.uint32(8)) ^ CRC_TABLE[t[k - 1] & 0xFF]
    return t

CRC_TABLES8 = _crc_tables8()


def crc32_ref(payload: jax.Array, plen: jax.Array) -> jax.Array:
    """Per-packet CRC32 over payload[:plen].  payload (N, MTU) uint8,
    plen (N,) int32 -> (N,) uint32."""
    table = jnp.asarray(CRC_TABLE.astype(np.int64)).astype(jnp.uint32)
    data = payload.astype(jnp.uint32)
    n, mtu = payload.shape
    crc0 = jnp.full((n,), 0xFFFFFFFF, jnp.uint32)

    def body(i, crc):
        byte = data[:, i]
        new = (crc >> 8) ^ table[(crc ^ byte) & 0xFF]
        return jnp.where(i < plen, new, crc)

    crc = jax.lax.fori_loop(0, mtu, body, crc0)
    return crc ^ jnp.uint32(0xFFFFFFFF)


# ===========================================================================
# DPI ternary MLP (paper §5.1.2): 64-byte beat -> score
# ===========================================================================

DPI_DIMS = (64, 128, 64)      # input, hidden1, hidden2 (output dim 1)


def dpi_scores_ref(payload: jax.Array, params: Dict) -> jax.Array:
    """payload (N, MTU) uint8 -> per-beat scores (N, MTU//64) float32.

    params: w1 (64,128) int8 ternary, s1 (); w2 (128,64) int8, s2 ();
            w3 (64,1) int8, s3 (); biases b1,b2 float32."""
    n, mtu = payload.shape
    beats = mtu // 64
    x = payload.reshape(n * beats, 64).astype(jnp.float32) / 128.0 - 1.0
    h = jax.nn.relu(x @ (params["w1"].astype(jnp.float32) * params["s1"])
                    + params["b1"])
    h = jax.nn.relu(h @ (params["w2"].astype(jnp.float32) * params["s2"])
                    + params["b2"])
    y = h @ (params["w3"].astype(jnp.float32) * params["s3"])
    return y[:, 0].reshape(n, beats)


# ===========================================================================
# DLRM preprocessing (paper §8.1): Neg2Zero -> Log (dense), Modulus (sparse)
# ===========================================================================

def preproc_ref(recs: jax.Array, n_dense: int, modulus: int) -> jax.Array:
    """recs (M, n_dense+n_sparse) int32.  Dense part: clip negatives to
    zero then log1p, stored as float32 bit pattern; sparse part: value
    mod ``modulus`` (non-negative)."""
    dense = recs[:, :n_dense]
    sparse = recs[:, n_dense:]
    d = jnp.log1p(jnp.maximum(dense.astype(jnp.float32), 0.0))
    d_bits = jax.lax.bitcast_convert_type(d, jnp.int32)
    s = jnp.remainder(sparse, modulus)
    return jnp.concatenate([d_bits, s], axis=1)
