"""Segmented payload-reduction kernel — the math of the collective
subsystem (ring reduce-scatter / allreduce and the in-fabric reduction
offload of ``repro.core.collectives``).

The operation: fold K contribution payloads (rows) into one, summing
element-wise in **row order** — ``((x0 + x1) + x2) + ...`` — a strict
left fold.  Order is part of the contract: float32 addition is
commutative but not associative, and the collective layer's bit-identity
guarantee (ring schedule == switch offload == jnp oracle) holds exactly
because every path folds contributions in the same canonical order.

FPGA -> TPU design dual: on a SmartNIC this is the reduction engine
RecoNIC-style offloads place next to the DMA path, summing streams as
they arrive at line rate; the dual folds a (K, L) batch of payloads with
one jitted kernel — the Pallas variant tiles the element axis across the
grid and runs the K-deep fold in VMEM, the jnp oracle is the same fold
written as ``lax.fori_loop`` (bit-identical, property-tested in
tests/test_kernels.py).

Payloads are wire bytes (uint8); ``chunk_reduce`` bit-casts them to the
collective dtype, folds, and casts back — zero-copy in-graph, exactly
like the preprocessing service handles record words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_L = 512           # elements per grid tile (f32: 2 KB VMEM per row)
INTERPRET = jax.default_backend() == "cpu"

DTYPES = {"float32": jnp.float32, "int32": jnp.int32}


def reduce_fold_ref(x: jax.Array) -> jax.Array:
    """(K, L) -> (L,): strict left fold over rows (the jnp oracle)."""
    def step(i, acc):
        return acc + x[i]
    return jax.lax.fori_loop(1, x.shape[0], step, x[0])


def _fold_kernel(x_ref, o_ref):
    x = x_ref[...]                              # (K, BLOCK_L)

    def step(i, acc):
        return acc + x[i]

    o_ref[...] = jax.lax.fori_loop(1, x.shape[0], step, x[0])[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def reduce_fold_pallas(x: jax.Array, *, interpret: bool = INTERPRET
                       ) -> jax.Array:
    """(K, L) -> (L,): the same left fold, tiled over the element axis.
    Pad lanes compute garbage that is sliced off — rows are folded in
    identical order, so real lanes are bit-identical to the oracle."""
    k, n = x.shape
    pad = (-n) % BLOCK_L
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _fold_kernel,
        grid=((n + pad) // BLOCK_L,),
        in_specs=[pl.BlockSpec((k, BLOCK_L), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, BLOCK_L), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n + pad), x.dtype),
        interpret=interpret,
    )(xp)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("dtype", "impl"))
def chunk_reduce(payload: jax.Array, *, dtype: str = "float32",
                 impl: str = "pallas") -> jax.Array:
    """Fold K wire payloads into one: (K, L) uint8 -> (L,) uint8.

    ``L`` must be a multiple of the dtype width (collective chunks are
    element-aligned by construction).  ``dtype`` selects the element
    interpretation; ``impl`` selects the Pallas kernel or the jnp
    oracle (bit-identical either way)."""
    jt = DTYPES[dtype]
    k, nbytes = payload.shape
    width = jnp.dtype(jt).itemsize
    assert nbytes % width == 0, (nbytes, dtype)
    x = jax.lax.bitcast_convert_type(
        payload.reshape(k, nbytes // width, width), jt)
    fold = reduce_fold_pallas if impl == "pallas" else reduce_fold_ref
    folded = fold(x)                                    # (L/width,)
    back = jax.lax.bitcast_convert_type(
        folded.reshape(nbytes // width, 1), jnp.uint8)
    return back.reshape(nbytes)
