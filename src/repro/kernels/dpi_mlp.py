"""ML-based deep-packet-inspection Pallas kernel (paper §5.1.2).

A ternary fully-connected network (weights in {-1, 0, +1} with a float
scale, as produced by hls4ml-style quantization) scores every 64-byte
beat of every payload; the per-packet decision is the aggregated max.
On the FPGA this runs at 44 ns/beat beside the packet pipeline; the TPU
dual fuses the three matmuls over a tile of beats in one VMEM-resident
kernel, so the whole MLP is a single HBM round trip (the MXU-friendly
dims are multiples of 64/128).

``train_dpi_params`` trains the float model on synthetic "big-data
payloads vs. executables" (repro.data.dpi_dataset) and ternarizes —
detection quality is benchmarked in benchmarks/fig8_dpi.py.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as R

BLOCK_B = 512           # beats per tile
INTERPRET = jax.default_backend() == "cpu"
D_IN, D_H1, D_H2 = R.DPI_DIMS


def _dpi_kernel(beats_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                scales_ref, out_ref):
    x = beats_ref[...].astype(jnp.float32) / 128.0 - 1.0     # (BB, 64)
    s = scales_ref[...]                                      # (1, 3)
    h = jnp.maximum(
        jnp.dot(x, w1_ref[...].astype(jnp.float32) * s[0, 0],
                preferred_element_type=jnp.float32) + b1_ref[...], 0.0)
    h = jnp.maximum(
        jnp.dot(h, w2_ref[...].astype(jnp.float32) * s[0, 1],
                preferred_element_type=jnp.float32) + b2_ref[...], 0.0)
    y = jnp.dot(h, w3_ref[...].astype(jnp.float32) * s[0, 2],
                preferred_element_type=jnp.float32)
    out_ref[...] = y


@functools.partial(jax.jit, static_argnames=("interpret",))
def dpi_scores_pallas(payload: jax.Array, params: Dict, *,
                      interpret: bool = INTERPRET) -> jax.Array:
    """payload (N, MTU) uint8 -> per-beat scores (N, MTU//64) float32."""
    n, mtu = payload.shape
    beats = mtu // 64
    x = payload.reshape(n * beats, 64).astype(jnp.int32)
    m = x.shape[0]
    pad = (-m) % BLOCK_B
    x = jnp.pad(x, ((0, pad), (0, 0)))
    scales = jnp.stack([params["s1"], params["s2"], params["s3"]]
                       ).astype(jnp.float32)[None, :]
    out = pl.pallas_call(
        _dpi_kernel,
        grid=((m + pad) // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, D_IN), lambda i: (i, 0)),
            pl.BlockSpec((D_IN, D_H1), lambda i: (0, 0)),
            pl.BlockSpec((D_H1,), lambda i: (0,)),
            pl.BlockSpec((D_H1, D_H2), lambda i: (0, 0)),
            pl.BlockSpec((D_H2,), lambda i: (0,)),
            pl.BlockSpec((D_H2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, 1), jnp.float32),
        interpret=interpret,
    )(x, params["w1"].astype(jnp.int32), params["b1"],
      params["w2"].astype(jnp.int32), params["b2"],
      params["w3"].astype(jnp.int32), scales)
    return out[:m, 0].reshape(n, beats)


dpi_scores_ref = R.dpi_scores_ref


# ---------------------------------------------------------------------------
# Training + ternarization
# ---------------------------------------------------------------------------

def init_dpi_params(key) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (D_IN, D_H1)) * 0.2,
        "b1": jnp.zeros((D_H1,), jnp.float32),
        "w2": jax.random.normal(k2, (D_H1, D_H2)) * 0.2,
        "b2": jnp.zeros((D_H2,), jnp.float32),
        "w3": jax.random.normal(k3, (D_H2, 1)) * 0.2,
        "s1": jnp.asarray(1.0), "s2": jnp.asarray(1.0), "s3": jnp.asarray(1.0),
    }


def _float_forward(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return (h @ p["w3"])[:, 0]


def ternarize(params: Dict) -> Dict:
    """Magnitude-threshold ternarization with per-layer scale (TWN rule:
    threshold = 0.7 * mean|w|, scale = mean|w| over kept entries)."""
    out = {}
    for i, w_name in enumerate(("w1", "w2", "w3"), 1):
        w = np.asarray(params[w_name])
        thr = 0.7 * np.abs(w).mean()
        tern = np.sign(w) * (np.abs(w) > thr)
        kept = np.abs(w[np.abs(w) > thr])
        scale = float(kept.mean()) if kept.size else 1.0
        out[w_name] = jnp.asarray(tern, jnp.int8)
        out[f"s{i}"] = jnp.asarray(scale, jnp.float32)
    out["b1"] = jnp.asarray(params["b1"], jnp.float32)
    out["b2"] = jnp.asarray(params["b2"], jnp.float32)
    return out


def train_dpi_params(beats: np.ndarray, labels: np.ndarray,
                     steps: int = 300, lr: float = 3e-3, seed: int = 0
                     ) -> Dict:
    """beats (M, 64) uint8, labels (M,) {0,1}.  Returns ternary params."""
    x = jnp.asarray(beats, jnp.float32) / 128.0 - 1.0
    y = jnp.asarray(labels, jnp.float32)
    p = init_dpi_params(jax.random.key(seed))

    def loss_fn(p):
        logits = _float_forward(p, x)
        return jnp.mean(jnp.maximum(logits, 0) - logits * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    for _ in range(steps):
        p, l = step(p)
    return ternarize(p)
