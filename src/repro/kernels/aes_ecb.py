"""AES-128-ECB Pallas kernel (paper §5.1.1 on-datapath crypto service).

TPU adaptation of the FPGA's 10-stage AES pipeline: instead of one block
per clock through unrolled rounds, the kernel processes a VMEM tile of
``BLOCK_N`` 16-byte blocks per grid step with the 10 rounds fully
unrolled inside the kernel (static Python loop -> straight-line VPU
code).  S-box lookups are VMEM gathers; GF(2^8) math is shift/xor on
int32 lanes (the VPU has no 8-bit lanes, so bytes ride in int32).

Validated in interpret mode against ref.py (which itself is pinned to
FIPS-197 vectors in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as R
from repro.kernels.ref import expand_key  # re-export for services

BLOCK_N = 512           # blocks (of 16 bytes) per VMEM tile: 512*16*4B = 32KiB

INTERPRET = jax.default_backend() == "cpu"


def _xt(x):
    return ((x << 1) ^ jnp.where((x & 0x80) != 0, 0x1B, 0)) & 0xFF


def _encrypt_kernel(blocks_ref, rk_ref, sbox_ref, sidx_ref, out_ref):
    sbox = sbox_ref[...]
    sidx = sidx_ref[...]
    st = blocks_ref[...]
    rk = rk_ref[...]
    st = st ^ rk[0][None, :]
    for r in range(1, 10):
        st = jnp.take(sbox, st, axis=0)
        st = jnp.take(st, sidx, axis=1)
        st = R._mix_columns(st)
        st = st ^ rk[r][None, :]
    st = jnp.take(sbox, st, axis=0)
    st = jnp.take(st, sidx, axis=1)
    st = st ^ rk[10][None, :]
    out_ref[...] = st


def _decrypt_kernel(blocks_ref, rk_ref, sbox_ref, sidx_ref, out_ref):
    inv_sbox = sbox_ref[...]
    iidx = sidx_ref[...]
    st = blocks_ref[...]
    rk = rk_ref[...]
    st = st ^ rk[10][None, :]
    for r in range(9, 0, -1):
        st = jnp.take(st, iidx, axis=1)
        st = jnp.take(inv_sbox, st, axis=0)
        st = st ^ rk[r][None, :]
        st = R._inv_mix_columns(st)
    st = jnp.take(st, iidx, axis=1)
    st = jnp.take(inv_sbox, st, axis=0)
    st = st ^ rk[0][None, :]
    out_ref[...] = st


@functools.partial(jax.jit, static_argnames=("decrypt", "interpret"))
def aes_ecb_pallas(blocks: jax.Array, round_keys, *, decrypt: bool = False,
                   interpret: bool = INTERPRET) -> jax.Array:
    """blocks (N, 16) uint8 -> (N, 16) uint8."""
    n = blocks.shape[0]
    pad = (-n) % BLOCK_N
    x = jnp.pad(blocks, ((0, pad), (0, 0))).astype(jnp.int32)
    rk = jnp.asarray(round_keys).astype(jnp.int32)
    kernel = _decrypt_kernel if decrypt else _encrypt_kernel
    sbox = jnp.asarray(R.INV_SBOX if decrypt else R.SBOX)
    sidx = jnp.asarray(R._INV_SHIFT_IDX if decrypt else R._SHIFT_IDX)
    out = pl.pallas_call(
        kernel,
        grid=((n + pad) // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, 16), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 16), jnp.int32),
        interpret=interpret,
    )(x, rk, sbox, sidx)
    return out[:n].astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("decrypt",))
def aes_ecb_ref(blocks: jax.Array, round_keys, *, decrypt: bool = False
                ) -> jax.Array:
    if decrypt:
        return R.aes_decrypt_ref(blocks, round_keys)
    return R.aes_encrypt_ref(blocks, round_keys)
