"""ICRC / CRC32 Pallas kernel (paper §4.5).

The FPGA meets line rate with three parallel combinational pipelines
(full 512-bit beats, 320-bit partial beats, 32-bit chunks).  The TPU
dual: *slice-by-8* table lookups — one fori_loop step folds 8 bytes with
eight 256-entry VMEM tables (the combinational tree becomes 8 parallel
gathers + xor reduce across int32 lanes), vectorized across a tile of
packets.  Ragged tails (plen % 8) fall back to the byte recurrence,
masked per packet — the analogue of the paper's 32-bit-chunk pipeline.

Polynomial: reflected 0xEDB88320 (Ethernet / RoCE ICRC).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as R

BLOCK_N = 64            # packets per tile
INTERPRET = jax.default_backend() == "cpu"


def _crc_kernel(data_ref, plen_ref, tabs_ref, out_ref):
    data = data_ref[...].astype(jnp.uint32)          # (BN, MTU)
    plen = plen_ref[...][:, 0]                       # (BN,)
    tabs = tabs_ref[...].astype(jnp.uint32)          # (8, 256)
    bn, mtu = data.shape
    n_words = mtu // 8

    def step(i, crc):
        chunk = jax.lax.dynamic_slice(data, (0, i * 8), (bn, 8))
        # ---- fast path: slice-by-8 (all 8 bytes inside the payload)
        lo = (crc ^ (chunk[:, 0] | (chunk[:, 1] << 8) |
                     (chunk[:, 2] << 16) | (chunk[:, 3] << 24)))
        fast = (tabs[7][(lo) & 0xFF] ^ tabs[6][(lo >> 8) & 0xFF]
                ^ tabs[5][(lo >> 16) & 0xFF] ^ tabs[4][(lo >> 24) & 0xFF]
                ^ tabs[3][chunk[:, 4]] ^ tabs[2][chunk[:, 5]]
                ^ tabs[1][chunk[:, 6]] ^ tabs[0][chunk[:, 7]])
        # ---- tail path: byte recurrence, masked per byte
        slow = crc
        for j in range(8):
            nxt = (slow >> 8) ^ tabs[0][(slow ^ chunk[:, j]) & 0xFF]
            slow = jnp.where(i * 8 + j < plen, nxt, slow)
        full = (i * 8 + 8) <= plen
        return jnp.where(full, fast, slow)

    crc0 = jnp.full((bn,), 0xFFFFFFFF, jnp.uint32)
    crc = jax.lax.fori_loop(0, n_words, step, crc0)
    out_ref[...] = (crc ^ jnp.uint32(0xFFFFFFFF))[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def crc32_pallas(payload: jax.Array, plen: jax.Array, *,
                 interpret: bool = INTERPRET) -> jax.Array:
    """payload (N, MTU) uint8, plen (N,) int32 -> (N,) uint32."""
    n, mtu = payload.shape
    assert mtu % 8 == 0
    pad = (-n) % BLOCK_N
    data = jnp.pad(payload, ((0, pad), (0, 0))).astype(jnp.int32)
    pl2 = jnp.pad(plen, (0, pad)).astype(jnp.int32)[:, None]
    tabs = jnp.asarray(R.CRC_TABLES8.astype(np.int64)).astype(jnp.int32)
    out = pl.pallas_call(
        _crc_kernel,
        grid=((n + pad) // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, mtu), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
            pl.BlockSpec((8, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 1), jnp.uint32),
        interpret=interpret,
    )(data, pl2, tabs)
    return out[:n, 0]


crc32_ref = R.crc32_ref
