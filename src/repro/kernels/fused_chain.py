"""Fused service-chain Pallas kernel (beyond-paper optimization).

The paper composes services as separate pipeline stages (AES core, DPI
core, ...), each with its own stream pass.  On TPU the equivalent chain
costs one HBM round trip *per service*; this kernel fuses
AES-ECB-decrypt + ML-DPI scoring into a single VMEM-resident pass —
payload bytes are read from HBM exactly once, decrypted in registers,
scored, and written once.  2x HBM-traffic reduction over the two-stage
chain for the receiver hot path (measured in benchmarks/fig8_dpi.py's
fused variant; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as R
from repro.kernels.ref import DPI_DIMS

BLOCK_N = 16            # packets per tile (x 4096 B = 256 KiB VMEM tile)
INTERPRET = jax.default_backend() == "cpu"
D_IN, D_H1, D_H2 = DPI_DIMS


def _fused_kernel(pay_ref, rk_ref, sbox_ref, sidx_ref, w1_ref, b1_ref,
                  w2_ref, b2_ref, w3_ref, scales_ref, out_ref, score_ref):
    pay = pay_ref[...]                       # (BN, MTU) int32 bytes
    bn, mtu = pay.shape
    rk = rk_ref[...]
    inv_sbox = sbox_ref[...]
    iidx = sidx_ref[...]

    # ---- AES-128-ECB decrypt, unrolled rounds (values stay in VMEM) ----
    st = pay.reshape(bn * (mtu // 16), 16)
    st = st ^ rk[10][None, :]
    for r in range(9, 0, -1):
        st = jnp.take(st, iidx, axis=1)
        st = jnp.take(inv_sbox, st, axis=0)
        st = st ^ rk[r][None, :]
        st = R._inv_mix_columns(st)
    st = jnp.take(st, iidx, axis=1)
    st = jnp.take(inv_sbox, st, axis=0)
    st = st ^ rk[0][None, :]
    plain = st.reshape(bn, mtu)
    out_ref[...] = plain

    # ---- DPI on the just-decrypted bytes (no HBM round trip) -----------
    s = scales_ref[...]
    x = plain.reshape(bn * (mtu // 64), 64).astype(jnp.float32) / 128.0 - 1.0
    h = jnp.maximum(
        jnp.dot(x, w1_ref[...].astype(jnp.float32) * s[0, 0],
                preferred_element_type=jnp.float32) + b1_ref[...], 0.0)
    h = jnp.maximum(
        jnp.dot(h, w2_ref[...].astype(jnp.float32) * s[0, 1],
                preferred_element_type=jnp.float32) + b2_ref[...], 0.0)
    y = jnp.dot(h, w3_ref[...].astype(jnp.float32) * s[0, 2],
                preferred_element_type=jnp.float32)
    score_ref[...] = jnp.max(y.reshape(bn, mtu // 64), axis=1)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_decrypt_dpi_pallas(payload: jax.Array, round_keys,
                             dpi_params: Dict, *,
                             interpret: bool = INTERPRET
                             ) -> Tuple[jax.Array, jax.Array]:
    """payload (N, MTU) uint8 -> (plaintext (N, MTU) uint8, max-beat
    DPI score (N,) float32) in ONE pass."""
    n, mtu = payload.shape
    pad = (-n) % BLOCK_N
    x = jnp.pad(payload, ((0, pad), (0, 0))).astype(jnp.int32)
    rk = jnp.asarray(round_keys).astype(jnp.int32)
    inv_sbox = jnp.asarray(R.INV_SBOX)
    iidx = jnp.asarray(R._INV_SHIFT_IDX)
    scales = jnp.stack([dpi_params["s1"], dpi_params["s2"],
                        dpi_params["s3"]]).astype(jnp.float32)[None, :]
    out, score = pl.pallas_call(
        _fused_kernel,
        grid=((n + pad) // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, mtu), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
            pl.BlockSpec((D_IN, D_H1), lambda i: (0, 0)),
            pl.BlockSpec((D_H1,), lambda i: (0,)),
            pl.BlockSpec((D_H1, D_H2), lambda i: (0, 0)),
            pl.BlockSpec((D_H2,), lambda i: (0,)),
            pl.BlockSpec((D_H2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, mtu), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, mtu), jnp.int32),
            jax.ShapeDtypeStruct((n + pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rk, inv_sbox, iidx,
      dpi_params["w1"].astype(jnp.int32), dpi_params["b1"],
      dpi_params["w2"].astype(jnp.int32), dpi_params["b2"],
      dpi_params["w3"].astype(jnp.int32), scales)
    return out[:n].astype(jnp.uint8), score[:n, 0]


def fused_decrypt_dpi_tile(payload: jax.Array, round_keys,
                           dpi_params: Dict, *, tile_pkts: int = BLOCK_N,
                           interpret: bool = INTERPRET
                           ) -> Tuple[jax.Array, jax.Array]:
    """Tile-granular streaming entry: run the fused decrypt+DPI pass over
    one fragment tile of at most ``tile_pkts`` packets as it arrives.

    Pads to the fixed ``(tile_pkts, MTU)`` shape so every mid-stream call
    hits one compiled executable (the streaming ingest hands tiles over
    the moment their bytes are acknowledged, including a short final
    tile).  Bit-identical per row to the one-shot ``fused_decrypt_dpi_
    pallas`` — AES and the DPI MLP are row-independent."""
    n = payload.shape[0]
    if n > tile_pkts:
        raise ValueError(f"tile carries {n} packets > tile_pkts={tile_pkts}")
    x = jnp.pad(payload, ((0, tile_pkts - n), (0, 0)))
    out, score = fused_decrypt_dpi_pallas(x, round_keys, dpi_params,
                                          interpret=interpret)
    return out[:n], score[:n]


def fused_decrypt_dpi_ref(payload: jax.Array, round_keys, dpi_params: Dict
                          ) -> Tuple[jax.Array, jax.Array]:
    """Two-pass oracle: decrypt, then DPI-score the plaintext."""
    n, mtu = payload.shape
    blocks = payload.reshape(n * (mtu // 16), 16)
    plain = R.aes_decrypt_ref(blocks, round_keys).reshape(n, mtu)
    scores = R.dpi_scores_ref(plain, dpi_params)
    return plain, jnp.max(scores, axis=1)
