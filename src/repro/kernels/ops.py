"""Jitted public wrappers for every kernel — the ``ops.py`` layer.

Each op dispatches impl="pallas" (pl.pallas_call, interpret-mode on CPU)
or impl="ref" (the pure-jnp oracle from ref.py)."""
from __future__ import annotations

from typing import Dict

import jax

from repro.kernels import aes_ecb as _aes
from repro.kernels import crc32 as _crc
from repro.kernels import dpi_mlp as _dpi
from repro.kernels import preproc as _pre
from repro.kernels import reduce as _red
from repro.kernels.ref import expand_key  # noqa: F401  (re-export)


def aes_ecb(blocks: jax.Array, round_keys, *, decrypt: bool = False,
            impl: str = "pallas") -> jax.Array:
    if impl == "pallas":
        return _aes.aes_ecb_pallas(blocks, round_keys, decrypt=decrypt)
    return _aes.aes_ecb_ref(blocks, round_keys, decrypt=decrypt)


def crc32(payload: jax.Array, plen: jax.Array, *, impl: str = "pallas"
          ) -> jax.Array:
    if impl == "pallas":
        return _crc.crc32_pallas(payload, plen)
    return _crc.crc32_ref(payload, plen)


def dpi_scores(payload: jax.Array, params: Dict, *, impl: str = "pallas"
               ) -> jax.Array:
    if impl == "pallas":
        return _dpi.dpi_scores_pallas(payload, params)
    return _dpi.dpi_scores_ref(payload, params)


def preproc(recs: jax.Array, n_dense: int, modulus: int, *,
            impl: str = "pallas") -> jax.Array:
    if impl == "pallas":
        return _pre.preproc_pallas(recs, n_dense, modulus)
    return _pre.preproc_ref(recs, n_dense, modulus)


def preproc_tile(recs: jax.Array, n_dense: int, modulus: int, *,
                 tile_recs: int = None) -> jax.Array:
    """Streaming (fixed-shape) preproc over one fragment tile — pads to
    ``tile_recs`` so mid-stream calls never recompile."""
    kw = {} if tile_recs is None else {"tile_recs": tile_recs}
    return _pre.preproc_tile(recs, n_dense, modulus, **kw)


def chunk_reduce(payload: jax.Array, *, dtype: str = "float32",
                 impl: str = "pallas") -> jax.Array:
    """Left-fold K collective payloads into one ((K, L) u8 -> (L,) u8)."""
    return _red.chunk_reduce(payload, dtype=dtype, impl=impl)
