"""DLRM preprocessing Pallas kernel (paper §8.1).

Fuses the paper's three stateless operators into one pass over a VMEM
tile of records:
  Neg2Zero  — clip negative dense features to zero
  Logarithm — log1p on dense features (large-value compression)
  Modulus   — restrict sparse feature range for the embedding tables

The FPGA achieves II=1 deep pipelines over 64-byte beats; the TPU dual
is a single elementwise kernel over (BLOCK_M, record) tiles — one HBM
read, one write, zero intermediate traffic (vs. three separate ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as R

BLOCK_M = 512
INTERPRET = jax.default_backend() == "cpu"


def _preproc_kernel(recs_ref, out_ref, *, n_dense: int, modulus: int):
    recs = recs_ref[...]                        # (BM, RW) int32
    dense = recs[:, :n_dense]
    sparse = recs[:, n_dense:]
    d = jnp.log1p(jnp.maximum(dense.astype(jnp.float32), 0.0))
    d_bits = jax.lax.bitcast_convert_type(d, jnp.int32)
    s = jnp.remainder(sparse, modulus)
    out_ref[...] = jnp.concatenate([d_bits, s], axis=1)


@functools.partial(jax.jit, static_argnames=("n_dense", "modulus",
                                             "interpret"))
def preproc_pallas(recs: jax.Array, n_dense: int, modulus: int, *,
                   interpret: bool = INTERPRET) -> jax.Array:
    """recs (M, RW) int32 -> (M, RW) int32 (dense part = f32 bits)."""
    m, rw = recs.shape
    pad = (-m) % BLOCK_M
    x = jnp.pad(recs, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_preproc_kernel, n_dense=n_dense, modulus=modulus),
        grid=((m + pad) // BLOCK_M,),
        in_specs=[pl.BlockSpec((BLOCK_M, rw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_M, rw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, rw), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:m]


def preproc_tile(recs: jax.Array, n_dense: int, modulus: int, *,
                 tile_recs: int = BLOCK_M,
                 interpret: bool = INTERPRET) -> jax.Array:
    """Tile-granular streaming entry: preprocess one fragment tile of at
    most ``tile_recs`` records the moment its bytes are acknowledged.

    A streaming ingest hands tiles over mid-transfer, so the tile is
    padded to the fixed ``(tile_recs, record)`` shape before entering the
    jitted kernel — every mid-stream call reuses ONE compiled executable
    regardless of how many records the final (short) tile carries.
    Numerics are identical to the one-shot ``preproc_pallas`` over the
    same rows (same kernel, element-wise), which is what lets streamed
    output be diffed bit-for-bit against the one-shot oracle."""
    n = recs.shape[0]
    if n > tile_recs:
        raise ValueError(f"tile carries {n} records > tile_recs={tile_recs}")
    x = jnp.pad(recs, ((0, tile_recs - n), (0, 0)))
    out = preproc_pallas(x, n_dense, modulus, interpret=interpret)
    return out[:n]


preproc_ref = R.preproc_ref
