"""Mixture-of-Experts FFN (DeepSeek-V2/V3 style).

Design (EP x TP, dry-run-friendly static shapes):

  * Routing: softmax top-k (+ load-balance aux loss) for V2, or
    sigmoid + aux-loss-free gate bias for V3 [arXiv:2408.15664].
  * Dispatch: sort-based capacity buckets built *per row* (a row = up to
    4096 contiguous tokens of one sequence), so the argsort never crosses
    a data shard -> no collective inside dispatch.
  * Expert compute: experts sharded over the "data" mesh axis (EP), the
    per-expert hidden dim over "model" (TP).  The relayout from
    row-sharded dispatch buckets to expert-sharded buckets is expressed
    as a sharding constraint — GSPMD lowers it to the EP all-to-all.
  * The token stream is processed in chunks of 16 rows (one per data
    shard) under lax.scan, bounding the all-to-all transient to
    ~0.6 GB/device even for deepseek-v3-671b @ train_4k.
  * Tokens over capacity lose that expert (standard "dropping"); shared
    experts are a dense always-on FFN so no token is ever fully dropped.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import ffn, ffn_spec
from repro.models.params import Spec
from repro.parallel.sharding import constrain

ROW_LEN = 4096          # tokens per dispatch row (<= one sequence)
ROWS_PER_CHUNK = 16     # rows processed per scan step (1 per data shard)
CAPACITY_FACTOR = 1.25
FLAT_PATH_MAX_TOKENS = 8192   # decode: gather-all dispatch below this


def _eax(cfg: ModelConfig) -> str:
    """Logical mesh axis for the expert dim (perf knob: 'ep2d' shards
    experts over (data x model) jointly -> no TP psum over the dispatched
    buffer, the dominant collective of the ep_tp baseline)."""
    return "expert2d" if cfg.expert_sharding == "ep2d" else "expert"


def moe_spec(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    eax = _eax(cfg)
    ffax = None if cfg.expert_sharding == "ep2d" else "expert_ff"
    spec = {
        "w_router": Spec((d, e), ("embed", None)),
        "w1": Spec((e, d, f), (eax, None, ffax)),
        "w3": Spec((e, d, f), (eax, None, ffax)),
        "w2": Spec((e, f, d), (eax, ffax, None)),
    }
    if cfg.aux_free_bias:
        spec["gate_bias"] = Spec((e,), (None,), "zeros", dtype="float32")
    if cfg.n_shared_experts:
        spec["shared"] = ffn_spec(d, cfg.n_shared_experts * f)
    return spec


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def route(cfg: ModelConfig, p, x: jax.Array):
    """x: (..., d) -> (ids (...,k), weights (...,k), aux_loss, load (E,))."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["w_router"].astype(jnp.float32))
    k, e = cfg.top_k, cfg.n_experts
    if cfg.gate_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores
        if cfg.aux_free_bias:
            sel = scores + jax.lax.stop_gradient(
                p["gate_bias"].astype(jnp.float32))
        _, ids = jax.lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        w = w * cfg.routed_scaling
        probs = scores / jnp.maximum(
            jnp.sum(scores, axis=-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, k)
        w = w * cfg.routed_scaling
    # load-balance statistics (flatten all token dims)
    flat_ids = ids.reshape(-1, k)
    load = jnp.zeros((e,), jnp.float32).at[flat_ids.reshape(-1)].add(1.0)
    load = load / jnp.maximum(jnp.sum(load), 1.0)
    aux = jnp.asarray(0.0, jnp.float32)
    if cfg.router_aux_coef:
        importance = jnp.mean(probs.reshape(-1, e), axis=0)
        aux = cfg.router_aux_coef * e * jnp.sum(load * importance)
    return ids, w.astype(x.dtype), aux, load


# ---------------------------------------------------------------------------
# Sort-based capacity dispatch (per row, no cross-shard ops)
# ---------------------------------------------------------------------------

def _dispatch_row(ids: jax.Array, w: jax.Array, n_tokens: int,
                  n_experts: int, capacity: int):
    """ids,w: (L, k) -> bucket token indices and weights (E, C).

    Sentinel index == L marks an empty slot (gathers a zero row)."""
    l, k = ids.shape
    flat_e = ids.reshape(-1)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(l, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_tok[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    rank = jnp.arange(l * k, dtype=jnp.int32) - group_start[se].astype(jnp.int32)
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity)           # OOB -> dropped write
    buf_tok = jnp.full((n_experts, capacity), l, jnp.int32)
    buf_tok = buf_tok.at[se, slot].set(st, mode="drop")
    buf_w = jnp.zeros((n_experts, capacity), w.dtype)
    buf_w = buf_w.at[se, slot].set(sw, mode="drop")
    return buf_tok, buf_w


def _combine_row(buf_tok, buf_w, y_e, n_tokens: int):
    """Scatter-add expert outputs back to token order. y_e: (E, C, d)."""
    d = y_e.shape[-1]
    y = jnp.zeros((n_tokens + 1, d), y_e.dtype)
    y = y.at[buf_tok].add(y_e * buf_w[..., None])
    return y[:n_tokens]


def _expert_ffn(cfg: ModelConfig, p, x_e: jax.Array,
                compute_dtype) -> jax.Array:
    """x_e: (..., E, C, d) expert-sharded buckets -> same shape."""
    w1 = p["w1"].astype(compute_dtype)
    w3 = p["w3"].astype(compute_dtype)
    w2 = p["w2"].astype(compute_dtype)
    h1 = jnp.einsum("...ecd,edf->...ecf", x_e, w1)
    h3 = jnp.einsum("...ecd,edf->...ecf", x_e, w3)
    h = jax.nn.silu(h1) * h3
    eax = _eax(cfg)
    ffax = None if cfg.expert_sharding == "ep2d" else "expert_ff"
    if x_e.ndim == 4:
        h = constrain(h, None, eax, None, ffax)
    else:
        h = constrain(h, eax, None, ffax)
    y = jnp.einsum("...ecf,efd->...ecd", h, w2)
    # NOTE (§Perf, refuted hypothesis #3): constraining this output's d
    # over "model" to force a reduce-scatter instead of the all-reduce
    # made the collective term WORSE (369 -> 430 s) — GSPMD re-shards the
    # combine inputs instead.  The identified real fix is a shard_map MoE
    # inner loop that combines per-shard partials BEFORE one psum of the
    # (16x smaller) token tensor; see EXPERIMENTS.md.
    return y


def moe_ffn(cfg: ModelConfig, p, x: jax.Array, compute_dtype=jnp.bfloat16
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Routed + shared expert FFN.  x: (B, S, d).

    Returns (y, aux_loss, expert_load)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tokens = b * s

    if n_tokens <= FLAT_PATH_MAX_TOKENS:
        y, aux, load = _moe_flat(cfg, p, x, compute_dtype)
    elif cfg.expert_sharding == "ep_sm":
        from repro.parallel.sharding import active_mesh
        if active_mesh() is not None:
            y, aux, load = _moe_chunked_shardmap(cfg, p, x, compute_dtype)
        else:  # no mesh context (smoke tests): pjit path
            y, aux, load = _moe_chunked(cfg, p, x, compute_dtype)
    else:
        y, aux, load = _moe_chunked(cfg, p, x, compute_dtype)

    if cfg.n_shared_experts:
        y = y + ffn(p["shared"], x, compute_dtype)
    return constrain(y, "batch", "seq", "d_model"), aux, load


def _moe_flat(cfg, p, x, compute_dtype):
    """Decode path: few tokens; gather-all, dispatch once, EP compute."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    xf = x.reshape(n, d)
    ids, w, aux, load = route(cfg, p, xf)
    # small-N floor: with few tokens, hot experts easily exceed the
    # proportional capacity — give decode enough headroom to avoid drops.
    cap = max(math.ceil(CAPACITY_FACTOR * n * k / e), min(n, 16))
    buf_tok, buf_w = _dispatch_row(ids, w, n, e, cap)
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = x_pad[buf_tok]                               # (E, C, d)
    eax = _eax(cfg)
    x_e = constrain(x_e, eax, None, None)              # EP all-to-all
    y_e = _expert_ffn(cfg, p, x_e, compute_dtype)
    y_e = constrain(y_e, eax, None, None)
    y = _combine_row(buf_tok, buf_w, y_e, n)
    return constrain(y.reshape(b, s, d), "batch", "seq", "d_model"), aux, load


def _expert_shard_map_fn(cfg, compute_dtype, n_data: int, n_model: int,
                         row_len: int):
    """Per-device body for the shard_map MoE (expert_sharding="ep_sm").

    The §Perf Cell-1 fix pjit could not express: run the expert FFN on
    f-shards and COMBINE the per-shard partials into the (10x smaller)
    token tensor BEFORE a single psum over "model" — instead of
    all-reducing the dispatched (tokens x k x capacity) buffer.

    Per-device inputs (shard_map slices):
      x_pad   (r_loc, L+1, d)   rows of this data shard (+ zero sentinel)
      buf_tok (r_loc, E, C)     dispatch buckets for those rows
      buf_w   (r_loc, E, C)
      w1/w3   (E_loc, d, f_loc) this device's expert/f shards
      w2      (E_loc, f_loc, d)
    Output: y (r_loc, L, d) — fully reduced.
    """
    def body(x_pad, buf_tok, buf_w, w1, w3, w2):
        r_loc, lp1, d = x_pad.shape
        e = buf_tok.shape[1]
        c = buf_tok.shape[2]
        e_loc = e // n_data
        # local gather of this shard's rows into all-expert buckets
        x_e = jax.vmap(lambda xp, bt: xp[bt])(x_pad, buf_tok)  # (r,E,C,d)
        # EP all-to-all over "data": split experts, concat rows ->
        # (r_loc * n_data, E_loc, C, d): every row shard's tokens for the
        # experts that live on this data shard
        # tiled a2a: split the expert axis across "data", concat source
        # shards on the row axis — one op, no 5D reshape round-trip (the
        # reshapes materialized two extra (r,E,C,d)-sized buffers)
        x_e = jax.lax.all_to_all(x_e, "data", split_axis=1, concat_axis=0,
                                 tiled=True)        # (r_loc*n_data, E_loc, C, d)
        h1 = jnp.einsum("recd,edf->recf", x_e, w1)
        h3 = jnp.einsum("recd,edf->recf", x_e, w3)
        y_e = jnp.einsum("recf,efd->recd", jax.nn.silu(h1) * h3, w2)
        # partial over "model" (f contracted locally).  Inverse tiled a2a
        # sends expert outputs back to their row shards, re-assembling
        # the full expert axis in original order.
        y_e = jax.lax.all_to_all(y_e, "data", split_axis=0, concat_axis=1,
                                 tiled=True)        # (r_loc, E, C, d)
        # ...combine to tokens while still partial-over-model...
        def combine(bt, bw, ye):
            y = jnp.zeros((lp1, d), ye.dtype)
            return y.at[bt].add(ye * bw[..., None])[:lp1 - 1]
        y = jax.vmap(combine)(buf_tok, buf_w, y_e)     # (r_loc, L, d)
        # ...then ONE reduction of the token tensor (10x smaller than the
        # dispatched buffer the pjit baseline all-reduces)
        return jax.lax.psum(y, "model")
    return body


def _moe_chunked_shardmap(cfg, p, x, compute_dtype):
    """expert_sharding="ep_sm": explicit-collective MoE (see above)."""
    import inspect
    try:                                  # jax >= 0.6 top-level API
        from jax import shard_map
    except ImportError:                   # older jax: experimental module
        from jax.experimental.shard_map import shard_map
    # kwarg name changed check_rep -> check_vma; key off the signature,
    # not the import location (the top-level alias predates the rename)
    sm_kwargs = ({"check_vma": False}
                 if "check_vma" in inspect.signature(shard_map).parameters
                 else {"check_rep": False})
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import active_mesh
    mesh = active_mesh()
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    row_len = min(s, ROW_LEN)
    n_rows = b * (s // row_len)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data, n_model = axis_sizes.get("data", 1), axis_sizes.get("model", 1)
    xr = x.reshape(n_rows, row_len, d)
    nc = max(1, n_rows // max(n_data, ROWS_PER_CHUNK))
    r = n_rows // nc
    xrc = jnp.moveaxis(xr.reshape(r, nc, row_len, d), 1, 0)
    cap = max(1, math.ceil(CAPACITY_FACTOR * row_len * k / e))
    dispatch_v = jax.vmap(lambda i, w: _dispatch_row(i, w, row_len, e, cap))
    w1 = p["w1"].astype(compute_dtype)
    w3 = p["w3"].astype(compute_dtype)
    w2 = p["w2"].astype(compute_dtype)
    body = _expert_shard_map_fn(cfg, compute_dtype, n_data, n_model, row_len)
    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"),
                  P("data", None, "model"), P("data", None, "model"),
                  P("data", "model", None)),
        out_specs=P("data"),
        **sm_kwargs)
    # recompute the expert segment in the backward instead of stashing
    # the a2a/dispatch intermediates per chunk (the stash was ~5 GB/chunk
    # x 59 layers of extra memory traffic — measured via top_bytes)
    smapped = jax.checkpoint(
        smapped, policy=jax.checkpoint_policies.nothing_saveable)

    def chunk_fn(carry, x_c):
        aux_acc, load_acc = carry
        x_c = constrain(x_c, "batch", None, None)
        ids, w, aux, load = route(cfg, p, x_c)
        buf_tok, buf_w = dispatch_v(ids, w)
        x_pad = jnp.concatenate(
            [x_c.astype(compute_dtype),
             jnp.zeros((r, 1, d), compute_dtype)], axis=1)
        y_c = smapped(x_pad, buf_tok, buf_w.astype(compute_dtype),
                      w1, w3, w2)
        return (aux_acc + aux, load_acc + load), y_c

    (aux, load), ys = jax.lax.scan(
        chunk_fn, (jnp.asarray(0.0, jnp.float32),
                   jnp.zeros((e,), jnp.float32)), xrc)
    ys = jnp.moveaxis(ys, 0, 1).reshape(n_rows, row_len, d)
    return ys.reshape(b, s, d).astype(x.dtype), aux / nc, load / nc


def _moe_chunked(cfg, p, x, compute_dtype):
    """Train/prefill path: rows of ROW_LEN tokens, chunks of 16 rows."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    row_len = min(s, ROW_LEN)
    assert s % row_len == 0, (s, row_len)
    n_rows = b * (s // row_len)
    xr = x.reshape(n_rows, row_len, d)
    nc = max(1, n_rows // ROWS_PER_CHUNK)
    r = n_rows // nc
    assert r * nc == n_rows, (n_rows, nc)
    # rows laid out (r, nc): chunk i takes one row from each shard's block
    xrc = xr.reshape(r, nc, row_len, d)
    xrc = jnp.moveaxis(xrc, 1, 0)                      # (nc, r, L, d)
    cap = max(1, math.ceil(CAPACITY_FACTOR * row_len * k / e))

    dispatch_v = jax.vmap(
        lambda i, w: _dispatch_row(i, w, row_len, e, cap))

    def body(carry, x_c):
        aux_acc, load_acc = carry
        x_c = constrain(x_c, "batch", None, None)      # (r, L, d) rows=data
        ids, w, aux, load = route(cfg, p, x_c)
        buf_tok, buf_w = dispatch_v(ids, w)            # (r, E, C)
        x_pad = jnp.concatenate(
            [x_c, jnp.zeros((r, 1, d), x_c.dtype)], axis=1)
        x_e = jax.vmap(lambda xp, bt: xp[bt])(x_pad, buf_tok)  # (r, E, C, d)
        eax = _eax(cfg)
        x_e = constrain(x_e, None, eax, None, None)        # EP all-to-all
        y_e = _expert_ffn(cfg, p, x_e, compute_dtype)
        y_e = constrain(y_e, None, eax, None, None)
        y_e = constrain(y_e, "batch", None, None, None)    # back to rows
        y_c = jax.vmap(_combine_row, in_axes=(0, 0, 0, None))(
            buf_tok, buf_w, y_e, row_len)
        return (aux_acc + aux, load_acc + load), y_c

    (aux, load), ys = jax.lax.scan(
        body, (jnp.asarray(0.0, jnp.float32), jnp.zeros((e,), jnp.float32)),
        xrc)
    ys = jnp.moveaxis(ys, 0, 1).reshape(n_rows, row_len, d)
    y = ys.reshape(b, s, d)
    return y, aux / nc, load / nc
