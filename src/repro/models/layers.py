"""Common neural-net layers (functional, params = nested dicts of arrays)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import Spec
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm_spec(dim: int):
    return {"scale": Spec((dim,), (None,), "zeros")}  # gemma-style (1+scale)


def rms_norm(params, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def layer_norm_spec(dim: int):
    return {
        "scale": Spec((dim,), (None,), "ones"),
        "bias": Spec((dim,), (None,), "zeros"),
    }


def layer_norm(params, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Activations / softcap
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def geglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.gelu(gate, approximate=True) * up


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def embedding_spec(vocab: int, d_model: int):
    return {"table": Spec((vocab, d_model), ("vocab", "embed"), "embed")}


def embed(params, tokens: jax.Array, compute_dtype) -> jax.Array:
    table = params["table"].astype(compute_dtype)
    y = jnp.take(table, tokens, axis=0)
    return constrain(y, "batch", "seq", "d_model")


def unembed(params, x: jax.Array, compute_dtype) -> jax.Array:
    """Tied LM head: logits = x @ table.T, vocab sharded over model."""
    table = params["table"].astype(compute_dtype)
    logits = jnp.einsum("...d,vd->...v", x, table)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Dense projections
# ---------------------------------------------------------------------------

def linear_spec(d_in: int, d_out: int, axes=("embed", "d_ff"), bias: bool = False):
    spec = {"w": Spec((d_in, d_out), axes)}
    if bias:
        spec["b"] = Spec((d_out,), (axes[1],), "zeros")
    return spec


def linear(params, x: jax.Array, compute_dtype) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, params["w"].astype(compute_dtype))
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def ffn_spec(d_model: int, d_ff: int, gated: bool = True, bias: bool = False):
    spec = {
        "w_up": Spec((d_model, d_ff), ("embed", "d_ff")),
        "w_down": Spec((d_ff, d_model), ("d_ff", "embed")),
    }
    if gated:
        spec["w_gate"] = Spec((d_model, d_ff), ("embed", "d_ff"))
    if bias:
        spec["b_up"] = Spec((d_ff,), ("d_ff",), "zeros")
        spec["b_down"] = Spec((d_model,), (None,), "zeros")
    return spec


def ffn(params, x: jax.Array, compute_dtype, act: str = "silu") -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(compute_dtype))
    if "b_up" in params:
        up = up + params["b_up"].astype(compute_dtype)
    if "w_gate" in params:
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(compute_dtype))
        h = swiglu(gate, up) if act == "silu" else geglu(gate, up)
    else:
        h = jax.nn.gelu(up, approximate=True) if act == "gelu" else jax.nn.silu(up)
    h = constrain(h, "batch", "seq", "d_ff")
    y = jnp.einsum("...f,fd->...d", h, params["w_down"].astype(compute_dtype))
    if "b_down" in params:
        y = y + params["b_down"].astype(compute_dtype)
    return constrain(y, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# RoPE (incl. per-layer-type theta and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,                 # (B, S, H, D)
    positions: jax.Array,         # (B, S) int32
    theta: float,
) -> jax.Array:
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,                 # (B, S, H, D)
    positions: jax.Array,         # (3, B, S) int32  — (t, h, w)
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: frequency bands split into (t, h, w)
    sections; each band rotates by its own position stream."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    # Build per-band position source: (B, S, half)
    splits = jnp.concatenate([
        jnp.full((sections[0],), 0, jnp.int32),
        jnp.full((sections[1],), 1, jnp.int32),
        jnp.full((sections[2],), 2, jnp.int32),
    ])
    pos = positions.astype(jnp.float32)                         # (3, B, S)
    pos_bsh = jnp.take(pos, splits, axis=0)                     # (half, B, S)
    pos_bsh = jnp.moveaxis(pos_bsh, 0, -1)                      # (B, S, half)
    ang = pos_bsh * freqs                                       # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
