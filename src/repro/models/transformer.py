"""Block assembly: unified decoder blocks (dense / MoE / MLA / sliding /
recurrent), scan-over-layers with remat, encoder-decoder support, and the
full-model apply functions (train forward, prefill, decode step)."""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (embed, embedding_spec, ffn, ffn_spec,
                                 layer_norm, layer_norm_spec, rms_norm,
                                 rms_norm_spec, softcap, unembed)
from repro.models.params import Spec
from repro.parallel.sharding import constrain

ATTN_KINDS = ("global", "local", "enc", "mla")
RECURRENT_KINDS = ("mlstm", "slstm", "rglru")


def _norm_spec(cfg: ModelConfig):
    return (layer_norm_spec(cfg.d_model) if cfg.norm_type == "ln"
            else rms_norm_spec(cfg.d_model))


def _norm(cfg: ModelConfig, p, x):
    return (layer_norm(p, x, cfg.norm_eps) if cfg.norm_type == "ln"
            else rms_norm(p, x, cfg.norm_eps))


# ---------------------------------------------------------------------------
# Per-block specs
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig, kind: str, ffn_kind: Optional[str],
               cross: bool = False) -> Dict[str, Any]:
    spec: Dict[str, Any] = {}
    if kind in ("global", "local", "enc"):
        spec["ln1"] = _norm_spec(cfg)
        spec["attn"] = attn.attn_spec(cfg, kind)
        if cfg.sandwich_norm:
            spec["post_attn"] = _norm_spec(cfg)
    elif kind == "mla":
        spec["ln1"] = _norm_spec(cfg)
        spec["attn"] = attn.mla_spec(cfg)
    elif kind == "mlstm":
        spec["ln1"] = _norm_spec(cfg)
        spec["mix"] = ssm.mlstm_block_spec(cfg)
    elif kind == "slstm":
        spec["ln1"] = _norm_spec(cfg)
        spec["mix"] = ssm.slstm_block_spec(cfg)
    elif kind == "rglru":
        spec["ln1"] = _norm_spec(cfg)
        spec["mix"] = ssm.rglru_block_spec(cfg)
    else:
        raise ValueError(kind)
    if cross:
        spec["ln_cross"] = _norm_spec(cfg)
        spec["cross"] = attn.attn_spec(cfg, "cross")
    if ffn_kind == "dense":
        spec["ln2"] = _norm_spec(cfg)
        spec["ffn"] = ffn_spec(cfg.d_model, cfg.d_ff, cfg.ffn_gated,
                               cfg.ffn_bias)
        if cfg.sandwich_norm:
            spec["post_ffn"] = _norm_spec(cfg)
    elif ffn_kind == "dense_first":
        spec["ln2"] = _norm_spec(cfg)
        spec["ffn"] = ffn_spec(cfg.d_model, cfg.dense_d_ff, cfg.ffn_gated,
                               cfg.ffn_bias)
    elif ffn_kind == "moe":
        spec["ln2"] = _norm_spec(cfg)
        spec["moe"] = moe_mod.moe_spec(cfg)
    return spec


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     cross_len: int = 0) -> Dict[str, Any]:
    spec: Dict[str, Any] = {}
    if kind in ("global", "local"):
        spec["self"] = attn.cache_entry_spec(cfg, kind, batch, max_len)
    elif kind == "mla":
        spec["self"] = attn.cache_entry_spec(cfg, "mla", batch, max_len)
    elif kind == "mlstm":
        spec["self"] = ssm.mlstm_cache_spec(cfg, batch)
    elif kind == "slstm":
        spec["self"] = ssm.slstm_cache_spec(cfg, batch)
    elif kind == "rglru":
        spec["self"] = ssm.rglru_cache_spec(cfg, batch)
    if cross_len:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        spec["cross"] = {
            "ck": Spec((batch, cross_len, kv, hd),
                       ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
            "cv": Spec((batch, cross_len, kv, hd),
                       ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        }
    return spec


# ---------------------------------------------------------------------------
# Per-block apply
# ---------------------------------------------------------------------------

def apply_block(
    cfg: ModelConfig,
    kind: str,
    ffn_kind: Optional[str],
    p: Dict[str, Any],
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, Any]] = None,
    cache_index: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], Tuple[jax.Array, jax.Array]]:
    """Returns (x, new_cache_entry, (aux_loss, expert_load))."""
    aux = jnp.asarray(0.0, jnp.float32)
    load = jnp.zeros((max(cfg.n_experts, 1),), jnp.float32)
    new_cache: Dict[str, Any] = {}
    self_cache = cache.get("self") if cache else None

    h = _norm(cfg, p["ln1"], x)
    if kind in ("global", "local", "enc"):
        y, c = attn.self_attention(
            cfg, p["attn"], h, kind=kind, positions=positions,
            cache=self_cache, cache_index=cache_index,
            compute_dtype=compute_dtype)
        if cfg.sandwich_norm:
            y = _norm(cfg, p["post_attn"], y)
    elif kind == "mla":
        y, c = attn.mla_attention(
            cfg, p["attn"], h, positions=positions, cache=self_cache,
            cache_index=cache_index, compute_dtype=compute_dtype)
    elif kind == "mlstm":
        y, c = ssm.mlstm_block(cfg, p["mix"], h, self_cache, compute_dtype)
    elif kind == "slstm":
        y, c = ssm.slstm_block(cfg, p["mix"], h, self_cache, compute_dtype)
    elif kind == "rglru":
        y, c = ssm.rglru_block(cfg, p["mix"], h, self_cache, compute_dtype)
    else:
        raise ValueError(kind)
    x = x + y
    if c is not None:
        new_cache["self"] = c

    if "cross" in p:
        h = _norm(cfg, p["ln_cross"], x)
        if cache is not None and "cross" in cache and enc_out is None:
            # decode: reuse cached cross K/V
            ck, cv = cache["cross"]["ck"], cache["cross"]["cv"]
            y = _cross_from_cache(cfg, p["cross"], h, ck, cv, compute_dtype)
            new_cache["cross"] = cache["cross"]
        else:
            y = attn.cross_attention(cfg, p["cross"], h, enc_out,
                                     compute_dtype)
            if cache is not None:
                ck = jnp.einsum("btd,dhk->bthk", enc_out,
                                p["cross"]["wk"].astype(compute_dtype))
                cv = jnp.einsum("btd,dhk->bthk", enc_out,
                                p["cross"]["wv"].astype(compute_dtype))
                new_cache["cross"] = {"ck": ck, "cv": cv}
        x = x + y

    if ffn_kind in ("dense", "dense_first"):
        h = _norm(cfg, p["ln2"], x)
        y = ffn(p["ffn"], h, compute_dtype, cfg.ffn_act)
        if cfg.sandwich_norm and "post_ffn" in p:
            y = _norm(cfg, p["post_ffn"], y)
        x = x + y
    elif ffn_kind == "moe":
        h = _norm(cfg, p["ln2"], x)
        y, aux, load = moe_mod.moe_ffn(cfg, p["moe"], h, compute_dtype)
        x = x + y
    return x, (new_cache if new_cache else None), (aux, load)


def _cross_from_cache(cfg, p, x, ck, cv, compute_dtype):
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    mask = jnp.ones((1, 1, 1, x.shape[1], ck.shape[1]), bool)
    out = attn._dot_attention(q, ck, cv, mask, scale, 0.0, cfg.attn_impl,
                              cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# Layer-stack layout
# ---------------------------------------------------------------------------

def _ffn_kind_for(cfg: ModelConfig, kind: str, is_first_dense: bool) -> Optional[str]:
    if kind in ("mlstm", "slstm"):
        return None                       # integrated in the block
    if is_first_dense:
        return "dense_first"
    return "moe" if cfg.n_experts else "dense"


def stack_layout(cfg: ModelConfig):
    """(first_dense_kinds, scanned_pattern, tail_kinds) for the decoder."""
    first = [("mla" if cfg.use_mla else "global", "dense_first")] \
        * cfg.first_dense_layers
    pat = [(k, _ffn_kind_for(cfg, k, False)) for k in cfg.pattern]
    tail = [(k, _ffn_kind_for(cfg, k, False)) for k in cfg.tail_pattern]
    return first, pat, tail


def decoder_spec(cfg: ModelConfig, cross: bool = False):
    from repro.models import params as P
    first, pat, tail = stack_layout(cfg)
    spec: Dict[str, Any] = {}
    for i, (k, fk) in enumerate(first):
        spec[f"first_{i}"] = block_spec(cfg, k, fk, cross)
    if cfg.n_blocks > 0:
        pat_spec = {f"sub{j}": block_spec(cfg, k, fk, cross)
                    for j, (k, fk) in enumerate(pat)}
        spec["blocks"] = P.stack(pat_spec, cfg.n_blocks)
    for i, (k, fk) in enumerate(tail):
        spec[f"tail_{i}"] = block_spec(cfg, k, fk, cross)
    return spec


def decoder_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                       cross_len: int = 0):
    from repro.models import params as P
    first, pat, tail = stack_layout(cfg)
    spec: Dict[str, Any] = {}
    for i, (k, _) in enumerate(first):
        spec[f"first_{i}"] = block_cache_spec(cfg, k, batch, max_len, cross_len)
    if cfg.n_blocks > 0:
        pat_spec = {f"sub{j}": block_cache_spec(cfg, k, batch, max_len, cross_len)
                    for j, (k, _) in enumerate(pat)}
        spec["blocks"] = P.stack(pat_spec, cfg.n_blocks)
    for i, (k, _) in enumerate(tail):
        spec[f"tail_{i}"] = block_cache_spec(cfg, k, batch, max_len, cross_len)
    return spec


def apply_decoder(
    cfg: ModelConfig,
    params: Dict[str, Any],
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, Any]] = None,
    cache_index: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
    train: bool = False,
    compute_dtype=jnp.bfloat16,
):
    """Runs first-dense layers, the scanned pattern blocks, and tail layers.

    Returns (x, new_cache, (aux_loss, expert_load))."""
    first, pat, tail = stack_layout(cfg)
    aux = jnp.asarray(0.0, jnp.float32)
    load = jnp.zeros((max(cfg.n_experts, 1),), jnp.float32)
    new_cache: Dict[str, Any] = {}

    def run_block(kind, fk, p, x, c):
        return apply_block(cfg, kind, fk, p, x, positions=positions,
                           cache=c, cache_index=cache_index, enc_out=enc_out,
                           compute_dtype=compute_dtype)

    for i, (k, fk) in enumerate(first):
        c = cache.get(f"first_{i}") if cache else None
        x, nc, (a, l) = run_block(k, fk, params[f"first_{i}"], x, c)
        aux, load = aux + a, load + l
        if nc is not None:
            new_cache[f"first_{i}"] = nc

    if cfg.n_blocks > 0:
        def scan_body(carry, xs):
            x, aux, load = carry
            if cache is not None:
                bp, bc = xs
            else:
                bp, bc = xs, None
            nc_out = {}
            for j, (k, fk) in enumerate(pat):
                c = bc.get(f"sub{j}") if bc else None
                x, nc, (a, l) = run_block(k, fk, bp[f"sub{j}"], x, c)
                aux, load = aux + a, load + l
                nc_out[f"sub{j}"] = nc if nc is not None else {}
            return (x, aux, load), nc_out

        body = scan_body
        if train and cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(scan_body, policy=policy)
        xs = (params["blocks"], cache["blocks"]) if cache is not None \
            else params["blocks"]
        (x, aux, load), ncs = jax.lax.scan(body, (x, aux, load), xs)
        if cache is not None:
            new_cache["blocks"] = ncs

    for i, (k, fk) in enumerate(tail):
        c = cache.get(f"tail_{i}") if cache else None
        x, nc, (a, l) = run_block(k, fk, params[f"tail_{i}"], x, c)
        aux, load = aux + a, load + l
        if nc is not None:
            new_cache[f"tail_{i}"] = nc

    return x, (new_cache if cache is not None else None), (aux, load)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encoder_spec(cfg: ModelConfig):
    from repro.models import params as P
    blk = block_spec(cfg, "enc", "dense")
    return {"blocks": P.stack(blk, cfg.n_encoder_layers),
            "ln_post": _norm_spec(cfg)}


def apply_encoder(cfg: ModelConfig, params, x, positions, train=False,
                  compute_dtype=jnp.bfloat16):
    def body(carry, bp):
        y, _, _ = apply_block(cfg, "enc", "dense", bp, carry,
                              positions=positions,
                              compute_dtype=compute_dtype)
        return y, None
    fn = body
    if train and cfg.remat:
        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return _norm(cfg, params["ln_post"], x)


def sinusoidal_positions(seq: int, dim: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


def sinusoidal_at(pos: jax.Array, dim: int, dtype=jnp.float32) -> jax.Array:
    """Sinusoidal embedding for a (possibly traced) scalar position."""
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.zeros((dim,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe.astype(dtype)
