"""Parameter specification trees.

Every module declares its parameters as a nested dict of ``Spec`` entries
(shape + logical axes + initializer).  From one spec tree we derive:

  * real parameters          (``init`` — used by smoke tests / examples)
  * ShapeDtypeStruct stand-ins (``shapes`` — used by the multi-pod dry-run,
    no device allocation ever happens for the full-size configs)
  * logical-axes tree        (``axes`` — resolved to NamedShardings)

Keeping these three views in lockstep from a single source is what lets
the dry-run lower 671B-parameter configs on a CPU container.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"      # fan_in | zeros | ones | normal | embed
    dtype: Optional[str] = None

    def __post_init__(self):  # pragma: no cover - NamedTuple has no post_init
        pass


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _leafs(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def validate(tree):
    for leaf in _leafs(tree):
        assert isinstance(leaf, Spec), f"non-Spec leaf {leaf!r}"
        assert len(leaf.shape) == len(leaf.axes), leaf


def stack(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim of size ``n`` (for scan-over-layers)."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.dtype),
        tree,
        is_leaf=is_spec,
    )


def shapes(tree, param_dtype: str):
    """ShapeDtypeStruct tree — the dry-run's zero-allocation params."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        tree,
        is_leaf=is_spec,
    )


def axes(tree):
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def _init_one(spec: Spec, key, param_dtype: str):
    dtype = jnp.dtype(spec.dtype or param_dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "embed":
        return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "fan_in":
        # Axes-aware fan-in: leading batch-like dims (scan stacking,
        # expert dims) do NOT contribute to fan-in; the output side is
        # the trailing head block, or everything-but-input when the last
        # axis is "embed" (projections back into the residual stream).
        core_shape, core_axes = [], []
        for d, a in zip(shape, spec.axes):
            if a in ("layers", "expert", "expert2d") and not core_shape:
                continue            # leading stacked/expert dim
            core_shape.append(d)
            core_axes.append(a)
        if not core_shape:
            core_shape, core_axes = list(shape), list(spec.axes)
        if len(core_shape) == 1:
            fan_in = core_shape[0]
        elif core_axes and core_axes[-1] == "embed":
            fan_in = int(np.prod(core_shape[:-1]))
        elif len(core_shape) >= 3:
            fan_in = int(np.prod(core_shape[:-2]))
        else:
            fan_in = core_shape[0]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def init(tree, key, param_dtype: str):
    """Materialize real parameters (smoke tests / examples only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k, param_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in _leafs(tree))


def param_bytes(tree, param_dtype: str) -> int:
    total = 0
    for s in _leafs(tree):
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype or param_dtype).itemsize
    return total
