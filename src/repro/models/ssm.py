"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Griffin RG-LRU.

mLSTM   — matrix-memory LSTM [arXiv:2405.04517], implemented in the
          chunkwise-parallel stabilized form (intra-chunk quadratic +
          inter-chunk recurrent state), O(S * chunk) memory; plus an O(1)
          recurrent step for decode.
sLSTM   — scalar-memory LSTM with exponential gating and a normalizer
          state; inherently sequential -> lax.scan over time.
RG-LRU  — real-gated linear recurrent unit [Griffin, arXiv:2402.19427];
          parallel via lax.associative_scan, O(1) decode step.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.params import Spec
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (width w) — shift-and-add form, shard-friendly
# ---------------------------------------------------------------------------

def conv1d_spec(width: int, dim: int):
    return {"w": Spec((width, dim), (None, "d_ff")),
            "b": Spec((dim,), ("d_ff",), "zeros")}


def causal_conv1d(params, x: jax.Array, state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x: (B, S, D). state: (B, w-1, D) trailing inputs from the past."""
    w = params["w"].shape[0]
    wts = params["w"].astype(x.dtype)
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        xin = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    s = x.shape[1]
    y = jnp.zeros_like(x)
    for j in range(w):
        y = y + xin[:, j:j + s, :] * wts[w - 1 - j][None, None, :]
    y = y + params["b"].astype(x.dtype)
    new_state = xin[:, -(w - 1):, :] if state is not None else None
    return y, new_state


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_block_spec(cfg: ModelConfig):
    d = cfg.d_model
    inner = 2 * d                       # projection factor 2 (xLSTM paper)
    nh = cfg.n_heads
    return {
        "w_up": Spec((d, 2 * inner), ("embed", "d_ff")),
        "conv": conv1d_spec(cfg.conv_width, inner),
        "wq": Spec((inner, inner), ("d_ff", None)),
        "wk": Spec((inner, inner), ("d_ff", None)),
        "wv": Spec((inner, inner), ("d_ff", None)),
        "w_if": Spec((inner, 2 * nh), ("d_ff", None)),
        "b_if": Spec((2 * nh,), (None,), "zeros"),
        "gn_scale": Spec((inner,), (None,), "ones"),
        "w_down": Spec((inner, d), ("d_ff", "embed")),
    }


def _mlstm_chunkwise(q, k, v, ig, fg, chunk: int, state=None):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B, H, S, dh); ig, fg: (B, H, S) gate pre-activations.
    state: optional (C, n, m) = ((B,H,dh,dh), (B,H,dh), (B,H)).
    Returns h: (B,H,S,dh) and final state.
    """
    b, h, s, dh = q.shape
    q = q * (1.0 / math.sqrt(dh))
    if s % chunk != 0:
        chunk = s                                  # single chunk fallback
    nc = s // chunk
    qc = q.reshape(b, h, nc, chunk, dh).astype(jnp.float32)
    kc = k.reshape(b, h, nc, chunk, dh).astype(jnp.float32)
    vc = v.reshape(b, h, nc, chunk, dh).astype(jnp.float32)
    igc = ig.reshape(b, h, nc, chunk).astype(jnp.float32)
    fgc = fg.reshape(b, h, nc, chunk).astype(jnp.float32)

    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        # with C0 = n0 = 0 the initial stabilizer value is mathematically
        # irrelevant; 0 avoids extreme exponents (-1e30 leaks NaNs into
        # XLA-fused exp chains under jit — verified empirically)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        C0, n0, m0 = [x.astype(jnp.float32) for x in state]

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        C, n, m = carry
        qb, kb, vb, ib, fb = xs                   # (B,H,L,...)
        logf = jax.nn.log_sigmoid(fb)             # (B,H,L)
        bcum = jnp.cumsum(logf, axis=-1)          # inclusive
        btot = bcum[..., -1]
        # stabilizers per query position t
        a = ib - bcum                             # i_s - b_s
        m_intra = bcum + jnp.max(jnp.where(
            tri, a[..., None, :], -60.0), axis=-1)        # (B,H,L)
        m_inter = bcum + m[..., None]
        m_t = jnp.maximum(m_intra, m_inter)
        # intra-chunk scores
        dmat = bcum[..., :, None] - bcum[..., None, :] + ib[..., None, :]
        dmat = jnp.where(tri, dmat - m_t[..., :, None], -60.0)
        smat = jnp.einsum("bhtd,bhsd->bhts", qb, kb) * jnp.exp(dmat)
        # inter-chunk
        scale_in = jnp.exp(bcum + m[..., None] - m_t)      # (B,H,L)
        h_inter = jnp.einsum("bhtd,bhde->bhte", qb, C) * scale_in[..., None]
        n_inter = jnp.einsum("bhtd,bhd->bht", qb, n) * scale_in
        num = h_inter + jnp.einsum("bhts,bhse->bhte", smat, vb)
        den = n_inter + jnp.sum(smat, axis=-1)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        m_next = jnp.maximum(m + btot,
                             jnp.max(ib + btot[..., None] - bcum, axis=-1))
        kv_scale = jnp.exp(ib + btot[..., None] - bcum - m_next[..., None])
        C_next = (C * jnp.exp(m + btot - m_next)[..., None, None]
                  + jnp.einsum("bhs,bhsd,bhse->bhde", kv_scale, kb, vb))
        n_next = (n * jnp.exp(m + btot - m_next)[..., None]
                  + jnp.einsum("bhs,bhsd->bhd", kv_scale, kb))
        return (C_next, n_next, m_next), hout

    (Cf, nf, mf), hs = jax.lax.scan(
        body, (C0, n0, m0),
        tuple(jnp.moveaxis(x, 2, 0) for x in (qc, kc, vc, igc, fgc)))
    hs = jnp.moveaxis(hs, 0, 2).reshape(b, h, s, dh)
    return hs.astype(v.dtype), (Cf, nf, mf)


def _mlstm_step(q, k, v, ig, fg, state):
    """O(1) recurrent decode step. q,k,v: (B,H,dh); ig,fg: (B,H)."""
    C, n, m = state
    dh = q.shape[-1]
    q = q.astype(jnp.float32) * (1.0 / math.sqrt(dh))
    k, v = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, ig.astype(jnp.float32))
    fs = jnp.exp(logf + m - m_new)
    is_ = jnp.exp(ig.astype(jnp.float32) - m_new)
    C_new = fs[..., None, None] * C + is_[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = fs[..., None] * n + is_[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C_new, n_new, m_new)


def _group_rms(x, scale, nh, eps):
    """Per-head RMS norm over the head dim ('group norm' of xLSTM)."""
    b = x.shape[:-1]
    d = x.shape[-1]
    xh = x.reshape(*b, nh, d // nh).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + eps)
    return (xh.reshape(*b, d) * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_block(cfg: ModelConfig, p, x: jax.Array, cache=None,
                compute_dtype=jnp.bfloat16):
    """Pre-up-projection mLSTM block.  x: (B,S,d). cache: dict or None."""
    d = cfg.d_model
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    b, s, _ = x.shape

    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(compute_dtype))
    up = constrain(up, "batch", "seq", "d_ff")
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xc, conv_new = causal_conv1d(p["conv"], xm, conv_state)
    xc = jax.nn.silu(xc)
    q = jnp.einsum("bsf,fg->bsg", xc, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bsf,fg->bsg", xc, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsf,fg->bsg", xm, p["wv"].astype(compute_dtype))
    gates = (jnp.einsum("bsf,fg->bsg", xc, p["w_if"].astype(compute_dtype))
             + p["b_if"].astype(compute_dtype))
    ig, fg = gates[..., :nh], gates[..., nh:]

    def heads(t):  # (B,S,inner) -> (B,H,S,dh)
        return t.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)

    new_cache = None
    if cache is None:
        h, _ = _mlstm_chunkwise(heads(q), heads(k), heads(v),
                                ig.transpose(0, 2, 1), fg.transpose(0, 2, 1),
                                cfg.mlstm_chunk)
    elif s > 1:   # prefill: run chunkwise, keep final state
        h, (C, n, m) = _mlstm_chunkwise(
            heads(q), heads(k), heads(v),
            ig.transpose(0, 2, 1), fg.transpose(0, 2, 1), cfg.mlstm_chunk)
        new_cache = {"C": C, "n": n, "m": m, "conv": conv_new}
    else:         # decode
        state = (cache["C"], cache["n"], cache["m"])
        hq = heads(q)[:, :, 0], heads(k)[:, :, 0], heads(v)[:, :, 0]
        h1, (C, n, m) = _mlstm_step(*hq, ig[:, 0], fg[:, 0], state)
        h = h1[:, :, None, :]
        new_cache = {"C": C, "n": n, "m": m, "conv": conv_new}

    h = h.astype(compute_dtype)
    hflat = h.transpose(0, 2, 1, 3).reshape(b, s, inner)
    hflat = _group_rms(hflat, p["gn_scale"], nh, cfg.norm_eps)
    hflat = hflat * jax.nn.silu(z)
    y = jnp.einsum("bsf,fd->bsd", hflat, p["w_down"].astype(compute_dtype))
    return constrain(y, "batch", "seq", "d_model"), new_cache


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    inner = 2 * d
    nh = cfg.n_heads
    dh = inner // nh
    return {
        "C": Spec((batch, nh, dh, dh), ("batch", None, None, None), "zeros",
                  dtype="float32"),
        "n": Spec((batch, nh, dh), ("batch", None, None), "zeros",
                  dtype="float32"),
        "m": Spec((batch, nh), ("batch", None), "zeros", dtype="float32"),
        "conv": Spec((batch, cfg.conv_width - 1, inner),
                     ("batch", None, "d_ff"), "zeros"),
    }


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_block_spec(cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ffn_inner = int(d * 4 / 3) // 64 * 64 or 64   # GeGLU factor 4/3
    return {
        "conv": conv1d_spec(cfg.conv_width, d),
        "w_in": Spec((d, 4 * d), ("embed", "d_ff")),       # z, i, f, o
        "b_in": Spec((4 * d,), (None,), "zeros"),
        # recurrent block-diagonal weights: small init (0.02) — the
        # generic 3D fan-in rule would give std 1/sqrt(n_heads) and the
        # recurrence amplifies it exponentially over the sequence
        "r": Spec((nh, dh, 4 * dh), (None, None, None), "normal"),
        "gn_scale": Spec((d,), (None,), "ones"),
        "w_up": Spec((d, 2 * ffn_inner), ("embed", "d_ff")),
        "w_down": Spec((ffn_inner, d), ("d_ff", "embed")),
    }


def _slstm_cell(p, xg, state, nh):
    """One sLSTM step. xg: (B, 4d) input-gate preacts; state dict of (B,d)."""
    c, n, m, h = state
    b, d4 = xg.shape
    d = d4 // 4
    dh = d // nh
    hh = h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    # both xg and rec are laid out [z | i | f | o] per head groups flattened
    pre = xg.astype(jnp.float32) + rec
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    logf = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(logf + m, ip)
    i_ = jnp.exp(ip - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    # normalizer floored at 1 (|c| <= n by construction, so h stays in
    # [-1,1] either way): 1/n with n -> 0 makes backward cotangents
    # explode x1e6 and overflow bf16 across stacked blocks.  Same
    # stabilization family as mLSTM's max(|den|, exp(-m)) rule.
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_block(cfg: ModelConfig, p, x: jax.Array, cache=None,
                compute_dtype=jnp.bfloat16):
    """Post-up-projection sLSTM block. x: (B,S,d)."""
    d = cfg.d_model
    nh = cfg.n_heads
    b, s, _ = x.shape
    conv_state = cache["conv"] if cache is not None else None
    xc, conv_new = causal_conv1d(p["conv"], x, conv_state)
    xc = jax.nn.silu(xc)
    xg = (jnp.einsum("bsd,de->bse", xc, p["w_in"].astype(compute_dtype))
          + p["b_in"].astype(compute_dtype))

    if cache is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        state0 = (c0, c0, jnp.zeros((b, d), jnp.float32), c0)
    else:
        state0 = (cache["c"], cache["n"], cache["m"], cache["h"])

    def step(carry, xg_t):
        new = _slstm_cell(p, xg_t, carry, nh)
        # emit the per-step output already in compute dtype: keeps the
        # stacked ys buffer bf16 and prevents XLA from scheduling a
        # full-array convert inside the loop (verified via hlo_analysis
        # top_bytes — it was 2 x 1.7 TB/device of the memory term)
        return new, new[3].astype(compute_dtype)

    (c, n, m, h_last), hs = jax.lax.scan(step, state0,
                                         jnp.moveaxis(xg, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                            # (B,S,d)
    hs = _group_rms(hs, p["gn_scale"], nh, cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", hs, p["w_up"].astype(compute_dtype))
    g, u = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd",
                   jax.nn.gelu(g, approximate=True) * u,
                   p["w_down"].astype(compute_dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"c": c, "n": n, "m": m, "h": h_last, "conv": conv_new}
    return constrain(y, "batch", "seq", "d_model"), new_cache


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {
        "c": Spec((batch, d), ("batch", None), "zeros", dtype="float32"),
        "n": Spec((batch, d), ("batch", None), "zeros", dtype="float32"),
        "m": Spec((batch, d), ("batch", None), "zeros", dtype="float32"),
        "h": Spec((batch, d), ("batch", None), "zeros", dtype="float32"),
        "conv": Spec((batch, cfg.conv_width - 1, d),
                     ("batch", None, None), "zeros"),
    }


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma)
# ===========================================================================

RGLRU_C = 8.0


def rglru_block_spec(cfg: ModelConfig):
    d = cfg.d_model
    lru = cfg.lru_width or d
    return {
        "w_gate": Spec((d, lru), ("embed", "lru")),        # GeLU branch
        "w_x": Spec((d, lru), ("embed", "lru")),           # recurrent branch
        "conv": {"w": Spec((cfg.conv_width, lru), (None, "lru")),
                 "b": Spec((lru,), ("lru",), "zeros")},
        "w_a": Spec((lru, lru), ("lru", None)),            # recurrence gate
        "b_a": Spec((lru,), (None,), "zeros"),
        "w_i": Spec((lru, lru), ("lru", None)),            # input gate
        "b_i": Spec((lru,), (None,), "zeros"),
        "lam": Spec((lru,), (None,), "normal"),            # Λ parameter
        "w_down": Spec((lru, d), ("lru", "embed")),
    }


def _rglru_scan(a: jax.Array, b: jax.Array, h0=None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t via associative scan over axis 1."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2
    if h0 is not None:
        # fold the initial state into the first element
        b = b.at[:, 0].add(a[:, 0] * h0)
    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bb


def rglru_block(cfg: ModelConfig, p, x: jax.Array, cache=None,
                compute_dtype=jnp.bfloat16):
    """Griffin recurrent block. x: (B,S,d)."""
    b, s, _ = x.shape
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dl->bsl", x, p["w_gate"].astype(compute_dtype)),
        approximate=True)
    xr = jnp.einsum("bsd,dl->bsl", x, p["w_x"].astype(compute_dtype))
    xr = constrain(xr, "batch", "seq", "lru")
    conv_state = cache["conv"] if cache is not None else None
    xc, conv_new = causal_conv1d(p["conv"], xr, conv_state)

    r = jax.nn.sigmoid(
        jnp.einsum("bsl,lm->bsm", xc, p["w_a"].astype(compute_dtype))
        + p["b_a"].astype(compute_dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(
        jnp.einsum("bsl,lm->bsm", xc, p["w_i"].astype(compute_dtype))
        + p["b_i"].astype(compute_dtype)).astype(jnp.float32)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    bterm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated_x

    new_cache = None
    if cache is None:
        h = _rglru_scan(a, bterm)
    elif s > 1:  # prefill
        h = _rglru_scan(a, bterm, cache["h"].astype(jnp.float32))
        new_cache = {"h": h[:, -1], "conv": conv_new}
    else:        # decode step
        h1 = a[:, 0] * cache["h"].astype(jnp.float32) + bterm[:, 0]
        h = h1[:, None, :]
        new_cache = {"h": h1, "conv": conv_new}

    y = h.astype(compute_dtype) * gate
    y = jnp.einsum("bsl,ld->bsd", y, p["w_down"].astype(compute_dtype))
    return constrain(y, "batch", "seq", "d_model"), new_cache


def rglru_cache_spec(cfg: ModelConfig, batch: int):
    lru = cfg.lru_width or cfg.d_model
    return {
        "h": Spec((batch, lru), ("batch", "lru"), "zeros", dtype="float32"),
        "conv": Spec((batch, cfg.conv_width - 1, lru),
                     ("batch", None, "lru"), "zeros"),
    }
