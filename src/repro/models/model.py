"""Unified Model API: one class serving every assigned architecture.

Exposes exactly the entry points the launcher lowers:
  * ``loss(params, batch)``            -> train_4k
  * ``prefill(params, batch)``         -> prefill_32k
  * ``decode_step(params, cache, ...)``-> decode_32k / long_500k
plus spec trees (params / cache / inputs) so the multi-pod dry-run never
allocates real arrays for the full-size configs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, ShapeConfig
from repro.models import params as P
from repro.models import transformer as T
from repro.models.layers import embed, embedding_spec, rms_norm, softcap, unembed
from repro.models.params import Spec
from repro.parallel.sharding import constrain


def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy in fp32; targets < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = targets >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    t = jnp.clip(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ specs
    def param_spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        spec: Dict[str, Any] = {"embed": embedding_spec(cfg.vocab, cfg.d_model)}
        cross = cfg.is_encdec
        spec["decoder"] = T.decoder_spec(cfg, cross=cross)
        spec["final_norm"] = T._norm_spec(cfg)
        if cfg.is_encdec:
            spec["encoder"] = T.encoder_spec(cfg)
        if cfg.vision_stub:
            spec["vision_proj"] = {
                "w": Spec((cfg.d_model, cfg.d_model), ("embed", None))}
        if not cfg.tie_embeddings:
            spec["lm_head"] = {
                "w": Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))}
        if cfg.mtp:
            spec["mtp"] = {
                "proj": {"w": Spec((2 * cfg.d_model, cfg.d_model),
                                   ("embed", None))},
                "block": T.block_spec(
                    cfg, "mla" if cfg.use_mla else "global",
                    "dense_first" if cfg.dense_d_ff else "dense"),
                "norm": T._norm_spec(cfg),
            }
        return spec

    def param_shapes(self):
        return P.shapes(self.param_spec(), self.cfg.param_dtype)

    def param_axes(self):
        return P.axes(self.param_spec())

    def init_params(self, key):
        return P.init(self.param_spec(), key, self.cfg.param_dtype)

    def cache_spec(self, batch: int, max_len: int, enc_len: int = 0):
        cross_len = enc_len if self.cfg.is_encdec else 0
        return T.decoder_cache_spec(self.cfg, batch, max_len, cross_len)

    def cache_shapes(self, batch: int, max_len: int, enc_len: int = 0):
        return P.shapes(self.cache_spec(batch, max_len, enc_len),
                        self.cfg.compute_dtype)

    def init_cache(self, key, batch: int, max_len: int, enc_len: int = 0):
        cache = P.init(self.cache_spec(batch, max_len, enc_len), key,
                       self.cfg.compute_dtype)
        # empty attention-cache slots must be masked out: pos = -1
        return jax.tree_util.tree_map_with_path(
            lambda p, x: jnp.full_like(x, -1)
            if (p and getattr(p[-1], "key", None) == "pos") else x, cache)

    # -------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch, compute_dtype):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], compute_dtype)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
        if cfg.vision_stub and "vision_embed" in batch:
            v = jnp.einsum("bsd,de->bse",
                           batch["vision_embed"].astype(compute_dtype),
                           params["vision_proj"]["w"].astype(compute_dtype))
            m = batch["vision_mask"][..., None].astype(compute_dtype)
            x = x * (1 - m) + v * m
        if cfg.pos_embed == "sinusoidal":
            s = x.shape[1]
            x = x + T.sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
        return constrain(x, "batch", "seq", "d_model")

    def _positions(self, batch, seq: int):
        cfg = self.cfg
        if cfg.mrope_sections != (0, 0, 0) and "mrope_pos" in batch:
            return batch["mrope_pos"]
        b = batch["tokens"].shape[0]
        return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (b, seq))

    def _encode(self, params, batch, train, compute_dtype):
        cfg = self.cfg
        ae = batch["audio_embed"].astype(compute_dtype)
        s = ae.shape[1]
        enc_in = ae + T.sinusoidal_positions(s, cfg.d_model, ae.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                               (ae.shape[0], s))
        return T.apply_encoder(cfg, params["encoder"], enc_in, pos,
                               train=train, compute_dtype=compute_dtype)

    def _lm_logits(self, params, x, compute_dtype):
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, compute_dtype)
        else:
            logits = jnp.einsum("...d,dv->...v", x,
                                params["lm_head"]["w"].astype(compute_dtype))
            logits = constrain(logits, "batch", "seq", "vocab")
        return softcap(logits, cfg.final_softcap)

    # ------------------------------------------------------------------ train
    def forward(self, params, batch, train: bool = True):
        """Full-sequence forward -> (logits, aux, load)."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, batch, cd)
        seq = x.shape[1]
        positions = self._positions(batch, seq)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(params, batch, train, cd)
        x, _, (aux, load) = T.apply_decoder(
            cfg, params["decoder"], x, positions=positions, enc_out=enc_out,
            train=train, compute_dtype=cd)
        x = T._norm(cfg, params["final_norm"], x)
        return self._lm_logits(params, x, cd), aux, load, x

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, aux, load, h_final = self.forward(params, batch, train=True)
        loss = softmax_xent(logits, batch["targets"])
        metrics = {"xent": loss, "aux": aux, "expert_load": load}
        if cfg.mtp:
            loss_mtp = self._mtp_loss(params, batch, h_final)
            metrics["mtp"] = loss_mtp
            loss = loss + 0.3 * loss_mtp
        loss = loss + aux
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, batch, h_final):
        """DeepSeek-V3 multi-token prediction: predict t+2 from
        [h_t ; emb(token_{t+1})] through one extra block."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        tok_next = batch["targets"]                       # token at t+1
        emb_next = embed(params["embed"], jnp.clip(tok_next, 0), cd)
        h = jnp.concatenate([h_final, emb_next], axis=-1)
        h = jnp.einsum("bsd,de->bse", h,
                       params["mtp"]["proj"]["w"].astype(cd))
        seq = h.shape[1]
        positions = self._positions(batch, seq)
        h, _, _ = T.apply_block(
            cfg, "mla" if cfg.use_mla else "global",
            "dense_first" if cfg.dense_d_ff else "dense",
            params["mtp"]["block"], h, positions=positions,
            compute_dtype=cd)
        h = T._norm(cfg, params["mtp"]["norm"], h)
        logits = self._lm_logits(params, h, cd)
        # target at t+2 == targets shifted left by one; last position invalid
        t2 = jnp.concatenate(
            [batch["targets"][:, 1:],
             jnp.full_like(batch["targets"][:, :1], -1)], axis=1)
        return softmax_xent(logits, t2)

    # ---------------------------------------------------------------- serving
    def prefill(self, params, batch, cache):
        """Process the prompt, fill the cache, return last-token logits."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, batch, cd)
        seq = x.shape[1]
        positions = self._positions(batch, seq)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(params, batch, False, cd)
        x, new_cache, _ = T.apply_decoder(
            cfg, params["decoder"], x, positions=positions, cache=cache,
            cache_index=jnp.asarray(0, jnp.int32), enc_out=enc_out,
            train=False, compute_dtype=cd)
        x = T._norm(cfg, params["final_norm"], x[:, -1:])
        return self._lm_logits(params, x, cd), new_cache

    def decode_step(self, params, cache, tokens, index):
        """One token for every sequence in the batch.

        tokens: (B, 1) int32; index: scalar int32 current position."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        batch = {"tokens": tokens}
        x = embed(params["embed"], tokens, cd)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
        if cfg.pos_embed == "sinusoidal":
            pe = T.sinusoidal_at(index, cfg.d_model, x.dtype)
            x = x + pe[None, None, :]
        b = tokens.shape[0]
        if cfg.mrope_sections != (0, 0, 0):
            pos = jnp.broadcast_to(index.astype(jnp.int32), (3, b, 1))
        else:
            pos = jnp.broadcast_to(index.astype(jnp.int32), (b, 1))
        x, new_cache, _ = T.apply_decoder(
            cfg, params["decoder"], x, positions=pos, cache=cache,
            cache_index=index, train=False, compute_dtype=cd)
        x = T._norm(cfg, params["final_norm"], x)
        return self._lm_logits(params, x, cd), new_cache


# ---------------------------------------------------------------------------
# Input specs per (arch x shape) — ShapeDtypeStructs + logical axes
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (tree of ShapeDtypeStruct, tree of logical-axes tuples)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    def add(name, shp, ax, dtype=i32):
        specs[name] = jax.ShapeDtypeStruct(shp, dtype)
        axes[name] = ax

    if shape.kind == "train":
        add("tokens", (b, s), ("batch", "seq"))
        add("targets", (b, s), ("batch", "seq"))
        if cfg.is_encdec:
            add("audio_embed", (b, s, cfg.d_model),
                ("batch", "seq", "d_model"), jnp.dtype(cfg.compute_dtype))
        if cfg.vision_stub:
            add("vision_embed", (b, s, cfg.d_model),
                ("batch", "seq", "d_model"), jnp.dtype(cfg.compute_dtype))
            add("vision_mask", (b, s), ("batch", "seq"))
            add("mrope_pos", (3, b, s), (None, "batch", "seq"))
    elif shape.kind == "prefill":
        add("tokens", (b, s), ("batch", "seq"))
        if cfg.is_encdec:
            add("audio_embed", (b, s, cfg.d_model),
                ("batch", "seq", "d_model"), jnp.dtype(cfg.compute_dtype))
        if cfg.vision_stub:
            add("vision_embed", (b, s, cfg.d_model),
                ("batch", "seq", "d_model"), jnp.dtype(cfg.compute_dtype))
            add("vision_mask", (b, s), ("batch", "seq"))
            add("mrope_pos", (3, b, s), (None, "batch", "seq"))
    else:  # decode
        add("tokens", (b, 1), ("batch", None))
        if cfg.vision_stub:
            add("mrope_pos", (3, b, 1), (None, "batch", None))
    return specs, axes


ENC_LEN_FOR_DECODE = 1504  # whisper: 30 s of audio -> ~1500 frames (padded)
