"""DLRM — the paper's own §8 workload: deep learning recommendation
model (bottom MLP over dense features, embedding tables for sparse
features, pairwise dot interaction, top MLP) trained online behind the
BALBOA ingest path."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import DLRMConfig
from repro.models.params import Spec
from repro.models import params as P
from repro.parallel.sharding import constrain


class DLRM:
    def __init__(self, cfg: DLRMConfig):
        self.cfg = cfg

    def param_spec(self):
        cfg = self.cfg
        spec: Dict = {"tables": {}}
        for i in range(cfg.n_sparse):
            spec["tables"][f"t{i}"] = Spec(
                (cfg.embed_rows, cfg.embed_dim), ("vocab", None), "normal")
        dims = (cfg.n_dense,) + cfg.bottom_mlp
        spec["bottom"] = {
            f"l{i}": {"w": Spec((dims[i], dims[i + 1]), ("embed", "d_ff")),
                      "b": Spec((dims[i + 1],), (None,), "zeros")}
            for i in range(len(dims) - 1)}
        n_f = cfg.n_sparse + 1
        inter_dim = cfg.bottom_mlp[-1] + n_f * (n_f - 1) // 2
        tdims = (inter_dim,) + cfg.top_mlp
        spec["top"] = {
            f"l{i}": {"w": Spec((tdims[i], tdims[i + 1]), ("embed", "d_ff")),
                      "b": Spec((tdims[i + 1],), (None,), "zeros")}
            for i in range(len(tdims) - 1)}
        return spec

    def init_params(self, key):
        return P.init(self.param_spec(), key, self.cfg.param_dtype)

    def forward(self, params, dense: jax.Array, sparse: jax.Array
                ) -> jax.Array:
        """dense (B, n_dense) float32 (already preprocessed on-path!),
        sparse (B, n_sparse) int32 in [0, embed_rows)."""
        cfg = self.cfg
        x = dense
        for i in range(len(cfg.bottom_mlp)):
            l = params["bottom"][f"l{i}"]
            x = x @ l["w"] + l["b"]
            x = jax.nn.relu(x)
        embs = [params["tables"][f"t{i}"][sparse[:, i]]
                for i in range(cfg.n_sparse)]
        feats = jnp.stack([x] + embs, axis=1)       # (B, F, D)
        inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
        iu = jnp.triu_indices(feats.shape[1], k=1)
        z = jnp.concatenate([x, inter[:, iu[0], iu[1]]], axis=1)
        for i in range(len(cfg.top_mlp)):
            l = params["top"][f"l{i}"]
            z = z @ l["w"] + l["b"]
            if i < len(cfg.top_mlp) - 1:
                z = jax.nn.relu(z)
        return z[:, 0]

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        logits = self.forward(params, batch["dense"], batch["sparse"])
        y = batch["label"]
        nll = jnp.mean(jnp.maximum(logits, 0) - logits * y
                       + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        acc = jnp.mean((logits > 0) == (y > 0.5))
        return nll, {"loss": nll, "acc": acc}
