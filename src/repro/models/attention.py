"""Attention variants: GQA (full / sliding-window / bidirectional / cross),
logit softcaps, qk-norm, RoPE / M-RoPE, MLA (DeepSeek) with absorbed decode,
and KV caches (contiguous for global layers, ring for sliding-window
layers, compressed-latent for MLA)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, rms_norm, softcap
from repro.models.params import Spec
from repro.parallel.sharding import constrain

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, kind: str = "global"):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_qk_norm:
        spec["q_norm"] = Spec((hd,), (None,), "zeros")
        spec["k_norm"] = Spec((hd,), (None,), "zeros")
    return spec


def mla_spec(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    spec = {
        "wkv_a": Spec((d, kvr + dr), ("embed", "lora")),
        "kv_norm": Spec((kvr,), (None,), "zeros"),
        "wkv_b": Spec((kvr, h, dn + dv), ("lora", "heads", "head_dim")),
        "wo": Spec((h, dv, d), ("heads", "head_dim", "embed")),
    }
    if qr:
        spec["wq_a"] = Spec((d, qr), ("embed", "lora"))
        spec["q_norm"] = Spec((qr,), (None,), "zeros")
        spec["wq_b"] = Spec((qr, h, dn + dr), ("lora", "heads", "head_dim"))
    else:
        spec["wq"] = Spec((d, h, dn + dr), ("embed", "heads", "head_dim"))
    return spec


# ---------------------------------------------------------------------------
# Cache specs (per layer-kind)
# ---------------------------------------------------------------------------

def cache_entry_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind == "mla":
        return {
            "ckv": Spec((batch, max_len, cfg.kv_lora_rank),
                        ("batch", "cache_seq", None), "zeros"),
            "kpe": Spec((batch, max_len, cfg.qk_rope_head_dim),
                        ("batch", "cache_seq", None), "zeros"),
        }
    length = min(max_len, cfg.sliding_window) if kind == "local" else max_len
    kv_dtype = "int8" if cfg.kv_cache_quant else None
    spec = {
        "k": Spec((batch, length, kv, hd),
                  ("batch", "cache_seq", "kv_heads", "head_dim"), "zeros",
                  dtype=kv_dtype),
        "v": Spec((batch, length, kv, hd),
                  ("batch", "cache_seq", "kv_heads", "head_dim"), "zeros",
                  dtype=kv_dtype),
        # absolute positions of each slot; -1 = empty (masks padding)
        "pos": Spec((batch, length), ("batch", "cache_seq"), "zeros",
                    dtype="int32"),
    }
    if cfg.kv_cache_quant:
        # per-(slot, head) symmetric scales — the int8 KV cache halves
        # the dominant decode memory term (beyond-paper optimization)
        spec["k_scale"] = Spec((batch, length, kv),
                               ("batch", "cache_seq", "kv_heads"), "zeros",
                               dtype="float32")
        spec["v_scale"] = Spec((batch, length, kv),
                               ("batch", "cache_seq", "kv_heads"), "zeros",
                               dtype="float32")
    return spec


def _quant_kv(x: jax.Array):
    """(..., KV, D) -> int8 values + per-(.., KV) scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _dequant_kv(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Core dot-product attention (naive and chunked online-softmax)
# ---------------------------------------------------------------------------

def _build_mask(qpos, kpos, causal: bool, window: int) -> jax.Array:
    """(.., S, T) boolean mask from absolute positions.

    qpos: (B, S) or (S,);  kpos: (B, T) or (T,).  -1 in kpos = invalid slot.
    """
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    mask = k >= 0
    if causal:
        mask &= k <= q
    if window > 0:
        mask &= k > q - window
    return mask


def _dot_attention(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, KV, D)
    v: jax.Array,            # (B, T, KV, Dv)
    mask: jax.Array,         # broadcastable to (B, 1, 1, S, T)
    scale: float,
    cap: float,
    impl: str = "naive",
    chunk: int = 1024,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qh = q.reshape(b, s, kvh, g, d)
    while mask.ndim < 5:
        mask = mask[:, None] if mask.ndim >= 2 else mask[None]
    if impl == "chunked" and t > chunk and t % chunk == 0:
        return _dot_attention_chunked(qh, k, v, mask, scale, cap, chunk
                                      ).reshape(b, s, h, v.shape[-1])
    scores = jnp.einsum("bsngd,btnd->bnsgt", qh, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    # scores: (B, KV, S, G, T); mask: (B,1,1,S,T) -> align as (B,1,S,1,T).
    mask_al = mask.transpose(0, 1, 3, 2, 4)
    scores = jnp.where(mask_al, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnsgt,btnd->bsngd", probs, v)
    return out.reshape(b, s, h, v.shape[-1])


def _dot_attention_chunked(qh, k, v, mask, scale, cap, chunk):
    """Online-softmax (flash-style) attention scanned over KV chunks.

    qh: (B,S,KV,G,D); mask: (B,1,1,S,T).  Returns (B,S,KV,G,Dv).
    Memory: O(S * chunk) scores instead of O(S * T).
    """
    b, s, kvh, g, d = qh.shape
    t = k.shape[1]
    dv = v.shape[-1]
    n_chunks = t // chunk
    kc = k.reshape(b, n_chunks, chunk, kvh, d)
    vc = v.reshape(b, n_chunks, chunk, kvh, dv)
    maskc = jnp.broadcast_to(mask, (b, 1, 1, s, t)).reshape(
        b, 1, 1, s, n_chunks, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, mb = xs                              # (B,chunk,KV,D) ...
        sc = jnp.einsum("bsngd,btnd->bnsgt", qh, kb,
                        preferred_element_type=jnp.float32) * scale
        sc = softcap(sc, cap)
        # mb: (B,1,1,S,chunk) -> align to scores (B,KV,S,G,chunk)
        mb_al = mb.transpose(0, 1, 3, 2, 4)           # (B,1,S,1,chunk)
        sc = jnp.where(mb_al, sc, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnsgt,btnd->bnsgd", p.astype(vb.dtype), vb)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, s, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, s, g), jnp.float32)
    acc0 = jnp.zeros((b, kvh, s, g, dv), v.dtype)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.moveaxis(maskc, 4, 0)))
    out = acc / jnp.maximum(l_f, 1e-37)[..., None].astype(acc.dtype)
    return out.transpose(0, 2, 1, 3, 4)               # (B,S,KV,G,Dv)


def _sliding_attention_blocked(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, S, KV, D)
    v: jax.Array,            # (B, S, KV, Dv)
    qpos: jax.Array,         # (B, S)
    window: int,
    scale: float,
    cap: float,
    block_q: int = 2048,
) -> jax.Array:
    """Sliding-window attention in query blocks: block i attends only to
    the KV slice [i*bq - window, i*bq + bq) — O(S * (window + bq)) compute
    and score memory instead of O(S^2).  (attn_impl="blocked";
    EXPERIMENTS.md §Perf cell 2.)"""
    b, s, h, d = q.shape
    bq = min(block_q, window, s)
    while s % bq != 0:
        bq //= 2
    nb = s // bq
    span = window + bq
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    pp = jnp.pad(qpos, ((0, 0), (window, 0)), constant_values=-1)

    qb = jnp.moveaxis(q.reshape(b, nb, bq, h, d), 1, 0)          # (nb,B,bq,H,D)
    qpb = jnp.moveaxis(qpos.reshape(b, nb, bq), 1, 0)

    def body(_, xs):
        i, qi, qpi = xs
        kv_start = i * bq
        ki = jax.lax.dynamic_slice(kp, (0, kv_start, 0, 0),
                                   (b, span, k.shape[2], d))
        vi = jax.lax.dynamic_slice(vp, (0, kv_start, 0, 0),
                                   (b, span, v.shape[2], v.shape[-1]))
        kpi = jax.lax.dynamic_slice(pp, (0, kv_start), (b, span))
        mask = _build_mask(qpi, kpi, True, window)[:, None, None]
        out = _dot_attention(qi, ki, vi, mask, scale, cap, "naive")
        return 0, out

    _, outs = jax.lax.scan(body, 0,
                           (jnp.arange(nb), qb, qpb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA self-attention (full-seq and cached-decode)
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x, positions, theta, compute_dtype):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute_dtype))
    if cfg.use_qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rms_norm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    if cfg.mrope_sections != (0, 0, 0) and positions.ndim == 3:
        q = apply_mrope(q, positions, theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, theta, cfg.mrope_sections)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2d, theta)
        k = apply_rope(k, pos2d, theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def self_attention(
    cfg: ModelConfig,
    p,
    x: jax.Array,                     # (B, S, d_model)
    *,
    kind: str,                        # "global" | "local" | "enc"
    positions: jax.Array,             # (B,S) or (3,B,S) int32
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,   # scalar int32, decode position
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Optional[dict]]:
    theta = cfg.rope_theta
    if kind == "global" and cfg.rope_theta_global:
        theta = cfg.rope_theta_global
    window = cfg.sliding_window if kind == "local" else 0
    causal = kind != "enc"
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)

    q, k, v = _project_qkv(cfg, p, x, positions, theta, compute_dtype)
    b, s = x.shape[0], x.shape[1]
    pos2d = positions if positions.ndim == 2 else positions[0]

    new_cache = None
    use_blocked = (kind == "local" and cfg.attn_impl == "blocked"
                   and s > window and s > 1)
    if cache is None:
        kpos = pos2d
        mask = _build_mask(pos2d, kpos, causal, window)
        k_att, v_att = k, v
    elif s > 1:
        # prefill: fill the cache.  Local (ring) caches keep the last
        # ``window`` positions, *phase-aligned* so that subsequent decode
        # steps (slot = pos % length) overwrite the oldest entry.
        length = cache["k"].shape[1]
        if s >= length:
            k_w, v_w = k[:, -length:], v[:, -length:]
            p_w = pos2d[:, -length:]
            shift = s % length
            k_w = jnp.roll(k_w, shift, axis=1)
            v_w = jnp.roll(v_w, shift, axis=1)
            p_w = jnp.roll(p_w, shift, axis=1)
        else:
            pad = length - s
            k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            p_w = jnp.pad(pos2d, ((0, 0), (0, pad)), constant_values=-1)
        if cfg.kv_cache_quant:
            kq, ks = _quant_kv(k_w)
            vq, vs = _quant_kv(v_w)
            new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                         "pos": p_w.astype(jnp.int32)}
        else:
            new_cache = {"k": k_w.astype(cache["k"].dtype),
                         "v": v_w.astype(cache["v"].dtype),
                         "pos": p_w.astype(jnp.int32)}
        mask = _build_mask(pos2d, pos2d, causal, window)
        k_att, v_att = k, v
    else:
        # decode: scatter the new KV into the cache ring.
        length = cache["k"].shape[1]
        slot = (cache_index % length).astype(jnp.int32)
        if cfg.kv_cache_quant:
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            k_new = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                 (0, slot, 0, 0))
            v_new = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                 (0, slot, 0, 0))
            ks_new = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                  (0, slot, 0))
            vs_new = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                  (0, slot, 0))
            pos_new = jax.lax.dynamic_update_slice(
                cache["pos"], pos2d.astype(jnp.int32), (0, slot))
            new_cache = {"k": k_new, "v": v_new, "k_scale": ks_new,
                         "v_scale": vs_new, "pos": pos_new}
            k_att = _dequant_kv(k_new, ks_new, k.dtype)
            v_att = _dequant_kv(v_new, vs_new, v.dtype)
        else:
            k_new = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_new = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            pos_new = jax.lax.dynamic_update_slice(
                cache["pos"], pos2d.astype(jnp.int32), (0, slot))
            new_cache = {"k": k_new, "v": v_new, "pos": pos_new}
            new_cache["k"] = constrain(
                new_cache["k"], "batch", "cache_seq", "kv_heads", "head_dim")
            new_cache["v"] = constrain(
                new_cache["v"], "batch", "cache_seq", "kv_heads", "head_dim")
            k_att, v_att = new_cache["k"], new_cache["v"]
        mask = _build_mask(pos2d, pos_new, causal, window)

    if use_blocked and k_att is k:
        # O(S * (window + block)) sliding attention for full-seq local
        # layers (train/prefill); the cache write above is unaffected.
        out = _sliding_attention_blocked(q, k, v, pos2d, window, scale,
                                         cfg.attn_softcap)
    else:
        mask = mask[:, None, None] if mask.ndim == 3 \
            else mask[None, None, None]
        out = _dot_attention(q, k_att, v_att, mask, scale, cfg.attn_softcap,
                             "naive" if cfg.attn_impl == "blocked"
                             else cfg.attn_impl, cfg.attn_chunk)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute_dtype))
    return constrain(y, "batch", "seq", "d_model"), new_cache


def cross_attention(
    cfg: ModelConfig, p, x: jax.Array, kv_src: jax.Array,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """Encoder-decoder cross attention (no positions, no mask)."""
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"].astype(compute_dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"].astype(compute_dtype))
    mask = jnp.ones((1, 1, 1, x.shape[1], kv_src.shape[1]), bool)
    out = _dot_attention(q, k, v, mask, scale, 0.0, cfg.attn_impl,
                         cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute_dtype))
    return y


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek V2/V3)
# ---------------------------------------------------------------------------

def _mla_queries(cfg, p, x, pos2d, compute_dtype):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(compute_dtype))
        cq = rms_norm({"scale": p["q_norm"]}, cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(compute_dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute_dtype))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, pos2d, cfg.rope_theta)
    return q_nope, q_pe


def mla_attention(
    cfg: ModelConfig, p, x: jax.Array, *,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Optional[dict]]:
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr, h = cfg.kv_lora_rank, cfg.n_heads
    scale = 1.0 / math.sqrt(dn + dr)
    b, s, _ = x.shape
    pos2d = positions if positions.ndim == 2 else positions[0]

    q_nope, q_pe = _mla_queries(cfg, p, x, pos2d, compute_dtype)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute_dtype))
    ckv, k_pe = ckv_full[..., :kvr], ckv_full[..., kvr:]
    ckv = rms_norm({"scale": p["kv_norm"]}, ckv, cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], pos2d, cfg.rope_theta)[:, :, 0, :]

    wkv_b = p["wkv_b"].astype(compute_dtype)      # (kvr, H, dn+dv)
    wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]

    if cache is not None and s == 1:
        # ---- absorbed decode on the compressed latent cache --------------
        length = cache["ckv"].shape[1]
        ckv_new = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_index, 0))
        kpe_new = jax.lax.dynamic_update_slice(
            cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, cache_index, 0))
        ckv_new = constrain(ckv_new, "batch", "cache_seq", None)
        kpe_new = constrain(kpe_new, "batch", "cache_seq", None)
        new_cache = {"ckv": ckv_new, "kpe": kpe_new}
        # absorb wk_b into the query:  (B,1,H,dn) x (kvr,H,dn) -> (B,1,H,kvr)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk_b)
        sc = (jnp.einsum("bshr,btr->bhst", q_lat,
                         ckv_new.astype(compute_dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_pe,
                           kpe_new.astype(compute_dtype),
                           preferred_element_type=jnp.float32)) * scale
        tpos = jnp.arange(length)[None, :]
        valid = tpos <= cache_index
        sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1).astype(compute_dtype)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs,
                             ckv_new.astype(compute_dtype))
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat, wv_b)
    else:
        # ---- train / prefill: expand latents, standard attention ---------
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, wk_b)
        val = jnp.einsum("bsr,rhv->bshv", ckv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, dr))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        k = constrain(k, "batch", "seq", "heads", "head_dim")
        mask = _build_mask(pos2d, pos2d, True, 0)[:, None, None]
        out = _dot_attention(q, k, val, mask, scale, 0.0, cfg.attn_impl,
                             cfg.attn_chunk)
        new_cache = None
        if cache is not None:
            length = cache["ckv"].shape[1]
            pad = length - s
            ckv_w = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
            kpe_w = jnp.pad(k_pe, ((0, 0), (0, pad), (0, 0)))
            new_cache = {"ckv": ckv_w.astype(cache["ckv"].dtype),
                         "kpe": kpe_w.astype(cache["kpe"].dtype)}

    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(compute_dtype))
    return constrain(y, "batch", "seq", "d_model"), new_cache
