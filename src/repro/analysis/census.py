"""Host-sync census: measure, per simulated tick, how often the data
plane round-trips between device and host.

ROADMAP item 2 (the host-sync-free fused simulator core) needs a
baseline before it can claim progress; this instrument IS that
baseline.  The two dominant transfer channels in this codebase are

* D2H: ``np.asarray(<jax.Array>)`` — harvesting engine results,
  credits, payload columns back to the host object model;
* H2D: ``jnp.asarray(<np.ndarray>)`` / ``jax.device_put`` — shipping
  packet batches and credit columns into the jitted engines.

``sync_census()`` patches those three call sites (counting only, no
behavioral change) while one epoch of each fig-bench workload runs a
deterministic small-scale configuration.  The simulator is seeded and
tick-deterministic, so the counts are exact integers, stable across
machines — ``benchmarks/regress.py`` gates them lower-is-better: the
fused core drives them toward ~0, and nothing may quietly add a new
per-tick sync.

Workloads mirror the fig benches at smoke scale:

* ``fig6``  — 4:1 incast through the drop-tail switch (batched
  engine), counted over the ``step_network`` drain loop only;
* ``fig10`` — streamed DLRM ingest, 2 replicas, counted over
  ``fetch_shard_streaming``;
* ``fig11`` — 3-node ring allreduce, counted over ``allreduce``.
"""
from __future__ import annotations

import contextlib
from typing import Dict

import numpy as np


class SyncCounter:
    def __init__(self):
        self.d2h = 0
        self.h2d = 0


@contextlib.contextmanager
def sync_census():
    """Count device<->host transfers while the body runs.

    Patches ``numpy.asarray`` (D2H when handed a ``jax.Array``),
    ``jax.numpy.asarray`` and ``jax.device_put`` (H2D when handed host
    data).  Counting only — values pass through untouched."""
    import jax
    import jax.numpy as jnp

    c = SyncCounter()
    real_np_asarray = np.asarray
    real_jnp_asarray = jnp.asarray
    real_device_put = jax.device_put

    def np_asarray(a, *args, **kwargs):
        if isinstance(a, jax.Array):
            c.d2h += 1
        return real_np_asarray(a, *args, **kwargs)

    def jnp_asarray(a, *args, **kwargs):
        if isinstance(a, np.ndarray):
            c.h2d += 1
        return real_jnp_asarray(a, *args, **kwargs)

    def device_put(x, *args, **kwargs):
        if isinstance(x, np.ndarray):
            c.h2d += 1
        return real_device_put(x, *args, **kwargs)

    np.asarray = np_asarray
    jnp.asarray = jnp_asarray
    jax.device_put = device_put
    try:
        yield c
    finally:
        np.asarray = real_np_asarray
        jnp.asarray = real_jnp_asarray
        jax.device_put = real_device_put


def _result(c: SyncCounter, ticks: int) -> Dict:
    ticks = max(int(ticks), 1)
    return {"ticks": int(ticks), "d2h": int(c.d2h), "h2d": int(c.h2d),
            "d2h_per_tick": round(c.d2h / ticks, 4),
            "h2d_per_tick": round(c.h2d / ticks, 4)}


# --------------------------------------------------------------------------
# per-fig drivers (fixed seeds, smoke scale)
# --------------------------------------------------------------------------

def census_fig6(n_senders: int = 4, message_bytes: int = 32768,
                engine: str = "batched",
                epoch_mode: str = None) -> Dict:
    """One epoch of ``step_network`` over a drop-tail incast — the
    canonical fig6 congestion workload, counted over the drain loop
    only (setup H2D like table creation is not the tick loop's debt).
    ``epoch_mode='fused'`` drives the same world through
    ``run_network``'s fused epoch driver instead of per-tick stepping —
    the whole drain becomes O(1) pack/unpack transfers."""
    from repro.core import netsim
    from repro.core.rdma import (RdmaNode, network_pending, run_network,
                                 step_network)

    cfg = netsim.FabricConfig(port_bandwidth=4, port_delay=2,
                              queue_capacity=32, seed=7)
    fabric = netsim.SwitchedFabric(n_senders + 1, cfg)
    recv = RdmaNode(0, fabric, rx_credits=64, engine=engine)
    senders = [RdmaNode(i + 1, fabric, fc_window=16, engine=engine)
               for i in range(n_senders)]
    rng = np.random.default_rng(13)
    for s in senders:
        qpn, _, _ = s.init_rdma(message_bytes, recv)
        s.rdma_write(qpn, rng.integers(0, 256, message_bytes,
                                       dtype=np.uint8))
    nodes = [recv] + senders
    t0 = fabric.now
    with sync_census() as c:
        if epoch_mode:
            run_network(nodes, max_ticks=100_000, epoch_mode=epoch_mode)
        else:
            while network_pending(nodes) and fabric.now - t0 < 100_000:
                step_network(nodes)
    return _result(c, fabric.now - t0)


def census_fig10(n_pkts: int = 8, n_replicas: int = 2,
                 tile_pkts: int = 2, epoch_mode: str = None) -> Dict:
    """One streamed DLRM shard fetch (fig10's streaming arm).
    ``epoch_mode='fused'`` turns the per-tick advance inside the stream
    loop into watermark-bounded fused micro-epochs."""
    import jax
    from benchmarks.fig10_dlrm import (MOD, MTU, N_DENSE, N_SPARSE,
                                       _shard_fn)
    from repro.core.ingest import (BalboaIngest, IngestConfig,
                                   make_dlrm_tile_decoder)

    ing = BalboaIngest(
        IngestConfig(batch_bytes=n_pkts * MTU, n_storage_nodes=n_replicas,
                     link_bw_pkts_per_tick=1, tile_pkts=tile_pkts,
                     epoch_mode=epoch_mode),
        None, _shard_fn(n_pkts),
        tile_to_batch=make_dlrm_tile_decoder(N_DENSE, N_SPARSE, MOD))
    with sync_census() as c:
        batch, rep = ing.fetch_shard_streaming(0)
        jax.block_until_ready(batch["dense"])
    return _result(c, rep.ticks)


def census_fig11(world: int = 3, n_elems: int = 256,
                 epoch_mode: str = None) -> Dict:
    """One ring allreduce over the transport (fig11's ring arm)."""
    from repro.core.collectives import make_ring_group

    g = make_ring_group(world, max_bytes=n_elems * 4 + world * 4,
                        epoch_mode=epoch_mode)
    rng = np.random.default_rng(17)
    xs = [rng.standard_normal(n_elems).astype(np.float32)
          for _ in range(world)]
    t0 = g.net.now
    with sync_census() as c:
        g.allreduce(xs)
    return _result(c, g.net.now - t0)


def run_census() -> Dict:
    """The full census document (``BENCH_sync_census.json`` shape).

    Each fig workload is counted twice: the per-tick arm (the debt
    ROADMAP item 2 set out to retire) and the fused-epoch arm (what
    the fused core actually spends).  Both arms are committed and
    gated lower-is-better by ``benchmarks/regress.py`` — the per-tick
    arm so the legacy path cannot quietly grow new syncs, the fused
    arm so the fused core cannot quietly fall back to per-tick
    stepping (a fallback shows up as a ~10x jump in d2h_per_tick)."""
    return {"mode": "smoke",
            "census": {"fig6": census_fig6(),
                       "fig6_fused": census_fig6(epoch_mode="fused"),
                       "fig10": census_fig10(),
                       "fig10_fused": census_fig10(epoch_mode="fused"),
                       "fig11": census_fig11(),
                       "fig11_fused": census_fig11(epoch_mode="fused")}}
