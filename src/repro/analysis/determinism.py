"""Determinism lint: the AST pass family.

The simulator's contract is *tick determinism* — same seed, same packet
schedule, byte-identical Perfetto traces (``tests/test_telemetry.py``
pins it).  Everything that can silently break that contract is lint:

* ``wall-clock``      — reading the wall clock inside the data plane;
* ``unseeded-rng``    — the global numpy RNG or an unseeded
                        ``default_rng()``;
* ``set-iteration``   — iterating a set (hash-randomized order);
* ``dict-order``      — unsorted dict iteration whose loop body reaches
                        the wire or the event recorder, in the modules
                        where emission order is semantics;
* ``mutable-default`` — mutable default arguments (state leaks across
                        calls and across tests).

Scoping: inside ``src/repro`` each rule applies only where the hazard
is real (the wall clock is fine in ``launch/``; dict order is fine in a
pure lookup table).  Paths *outside* ``src/repro`` — e.g. the lint's
own test fixtures — get every rule, so fixtures can exercise all of
them without carve-outs.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List

from repro.analysis.violations import REPO_ROOT, Violation, relpath

# data-plane subtrees where wall-clock reads are forbidden (launch/,
# train/, benchmarks legitimately measure wall time)
WALL_CLOCK_SCOPE = ("core", "kernels", "data")

# modules where iteration order IS wire/trace order
ORDER_SENSITIVE = {"netsim.py", "rdma.py", "collectives.py",
                   "retransmit.py", "flow_control.py", "ingest.py",
                   "qp.py"}

# calls that put bytes on the wire, mutate retransmit state, or emit
# telemetry events — reaching one from inside an unordered iteration
# makes the iteration order observable
WIRE_FNS = {"send", "_send", "_send_ctrl", "_send_retx", "_dispatch",
            "inject", "rdma_write", "rdma_read", "on_packets", "hold",
            "_bump", "_resend", "_emit_message", "record", "_rec",
            "_enqueue", "enqueue"}

WALL_CLOCK_CALLS = {("time", "time"), ("time", "monotonic"),
                    ("time", "perf_counter"), ("time", "process_time"),
                    ("time", "monotonic_ns"), ("time", "perf_counter_ns"),
                    ("time", "time_ns")}


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('np.random.shuffle')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _in_repro(path: Path) -> bool:
    return "repro" in path.parts and "src" in path.parts


def _rule_applies(rule: str, path: Path) -> bool:
    if not _in_repro(path):
        return True                       # fixtures etc.: everything on
    parts = path.parts
    if rule == "wall-clock":
        return any(s in parts for s in WALL_CLOCK_SCOPE)
    if rule == "dict-order":
        return path.name in ORDER_SENSITIVE
    return True


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: Path):
        self.path = path
        self.rel = relpath(path)
        self.out: List[Violation] = []

    def _emit(self, rule: str, node: ast.AST, message: str):
        if _rule_applies(rule, self.path):
            self.out.append(Violation(rule, self.rel,
                                      getattr(node, "lineno", 0), message))

    # ---- wall-clock ----------------------------------------------------
    def _check_wall_clock(self, node: ast.Call):
        name = _dotted(node.func)
        parts = tuple(name.split("."))
        if len(parts) >= 2 and parts[-2:] in WALL_CLOCK_CALLS:
            self._emit("wall-clock", node,
                       f"wall-clock read `{name}()`")
        # argless datetime.now()/utcnow() (a tz-aware now(tz) is still
        # wall clock — flag both)
        if parts and parts[-1] in ("now", "utcnow", "today") \
                and "datetime" in parts:
            self._emit("wall-clock", node,
                       f"wall-clock read `{name}()`")

    # ---- unseeded-rng --------------------------------------------------
    def _check_rng(self, node: ast.Call):
        name = _dotted(node.func)
        parts = name.split(".")
        if len(parts) >= 3 and parts[-2] == "random" \
                and parts[-3] in ("np", "numpy"):
            leaf = parts[-1]
            if leaf == "default_rng":
                if not node.args and not node.keywords:
                    self._emit("unseeded-rng", node,
                               "unseeded `default_rng()` (OS-entropy "
                               "seed differs every run)")
            elif leaf not in ("Generator", "SeedSequence", "PCG64",
                              "Philox", "RandomState"):
                self._emit("unseeded-rng", node,
                           f"global numpy RNG `{name}()` — use a "
                           "`default_rng(seed)` stream")
        elif parts[-1] == "default_rng" and not node.args \
                and not node.keywords:
            self._emit("unseeded-rng", node,
                       "unseeded `default_rng()` (OS-entropy seed "
                       "differs every run)")

    def visit_Call(self, node: ast.Call):
        self._check_wall_clock(node)
        self._check_rng(node)
        self.generic_visit(node)

    # ---- set-iteration / dict-order ------------------------------------
    def _is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub)) \
                and (self._is_set_expr(node.left)
                     or self._is_set_expr(node.right)):
            return True                   # set algebra stays a set
        return False

    def _is_dict_view(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("items", "keys", "values")
                and not node.args)

    def _body_reaches_wire(self, body: Iterable[ast.AST]) -> str:
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    name = _dotted(sub.func)
                    leaf = name.split(".")[-1] if name else ""
                    if leaf in WIRE_FNS:
                        return leaf
        return ""

    def _check_iter(self, iter_node: ast.AST, loop: ast.AST,
                    body: Iterable[ast.AST]):
        if self._is_set_expr(iter_node):
            self._emit("set-iteration", loop,
                       "iteration over a set — order is "
                       "hash-randomized; sort it first")
        if self._is_dict_view(iter_node):
            wire = self._body_reaches_wire(body)
            if wire:
                view = iter_node.func.attr        # type: ignore[union-attr]
                owner = _dotted(iter_node.func.value)  # type: ignore
                self._emit(
                    "dict-order", loop,
                    f"unsorted `{owner or '<dict>'}.{view}()` iteration "
                    f"reaches the wire via `{wire}()` — iterate "
                    "`sorted(...)` so emission order is insertion-"
                    "history-free")

    def visit_For(self, node: ast.For):
        self._check_iter(node.iter, node, node.body)
        self.generic_visit(node)

    def visit_comprehension_set(self, node):
        for comp in node.generators:
            if self._is_set_expr(comp.iter):
                self._emit("set-iteration", node,
                           "comprehension over a set — order is "
                           "hash-randomized; sort it first")

    def visit_ListComp(self, node):
        self.visit_comprehension_set(node)
        self.generic_visit(node)

    def visit_GeneratorExp(self, node):
        self.visit_comprehension_set(node)
        self.generic_visit(node)

    # ---- mutable-default ----------------------------------------------
    def _check_defaults(self, node):
        a = node.args
        for arg, default in list(zip(a.args[::-1], a.defaults[::-1])) + [
                (kw, d) for kw, d in zip(a.kwonlyargs, a.kw_defaults) if d]:
            bad = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(default, ast.Call)
                and isinstance(default.func, ast.Name)
                and default.func.id in ("list", "dict", "set", "bytearray"))
            if bad:
                self._emit("mutable-default", default,
                           f"mutable default for `{arg.arg}` in "
                           f"`{node.name}()` — use None and allocate "
                           "inside")

    def visit_FunctionDef(self, node):
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node):
        self._check_defaults(node)
        self.generic_visit(node)


def lint_file(path: Path) -> List[Violation]:
    path = Path(path).resolve()
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=str(path))
    except (OSError, SyntaxError) as e:
        return [Violation("determinism-parse", relpath(path), 0,
                          f"cannot parse: {e}")]
    v = _Visitor(path)
    v.visit(tree)
    return v.out


def run(paths: Iterable[Path]) -> List[Violation]:
    """Lint every ``.py`` under ``paths`` (files or directories)."""
    out: List[Violation] = []
    for p in paths:
        p = Path(p)
        if not p.is_absolute():
            p = REPO_ROOT / p
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            if "__pycache__" in f.parts:
                continue
            out.extend(lint_file(f))
    return out
