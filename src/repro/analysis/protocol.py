"""Protocol-exhaustiveness pass: the edges where FSMs rot.

Three reconciliations, all cheap to check and all historically the
first thing to silently drift as a protocol grows:

* ``opcode-coverage`` — every opcode declared in ``core/packet.py``
  (``OPCODE_NAMES``) is handled somewhere: payload opcodes flow to the
  jitted RX engines (``PAYLOAD_OPS`` membership), control opcodes are
  dispatched by name in ``RdmaNode.on_packets``'s ``p.opcode ==
  pk.<OP>`` chain (read straight from the AST so a deleted branch is
  caught even though the ``else`` swallows it at run time).  The
  reverse direction too: a dispatch arm naming an undeclared opcode.
* ``event-kinds`` — every ``FlightRecorder`` emit site
  (``.record(tick, "<kind>", ...)`` / ``._rec("<kind>", ...)``) uses a
  kind registered in ``telemetry.EVENT_KINDS``, and every registered
  kind is emitted somewhere (a dead kind is a renamed emit site).
* ``counter-reconcile`` — ``pipeline.COUNTER_FIELDS`` (the columns the
  jitted engines carry), ``rdma.ENGINE_COUNTERS`` (the harvest map)
  and ``NodeStats`` (the host mirror) agree by name.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import List, Set, Tuple

from repro.analysis.violations import REPO_ROOT, Violation, relpath

CORE = REPO_ROOT / "src" / "repro" / "core"


def _parse(path: Path) -> ast.AST:
    return ast.parse(path.read_text(), filename=str(path))


# --------------------------------------------------------------------------
# opcode coverage
# --------------------------------------------------------------------------

def _dispatched_constant_names(rdma_tree: ast.AST) -> Tuple[Set[str], int]:
    """Packet-module constant names the ``RdmaNode.on_packets`` dispatch
    tests ``p.opcode`` against — both equality arms (``p.opcode ==
    pk.ACK``) and membership arms (``p.opcode in pk.PAYLOAD_OPS``)."""
    names: Set[str] = set()
    line = 0
    for node in ast.walk(rdma_tree):
        if isinstance(node, ast.FunctionDef) and node.name == "on_packets":
            line = node.lineno
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Compare):
                    continue
                sides = [sub.left] + list(sub.comparators)
                opcode_side = any(
                    isinstance(s, ast.Attribute) and s.attr == "opcode"
                    for s in sides)
                if not opcode_side:
                    continue
                for s in sides:
                    if isinstance(s, ast.Attribute) and s.attr != "opcode" \
                            and s.attr.isupper():
                        names.add(s.attr)
    return names, line


def check_opcodes() -> List[Violation]:
    from repro.core import packet as pk
    out: List[Violation] = []
    rdma_path = CORE / "rdma.py"
    dispatched, line = _dispatched_constant_names(_parse(rdma_path))
    if not dispatched:
        return [Violation("opcode-coverage", relpath(rdma_path), 0,
                          "could not locate the on_packets opcode "
                          "dispatch chain")]
    declared = dict(pk.OPCODE_NAMES)

    # resolve each dispatched constant: an int covers one opcode, a
    # tuple (e.g. PAYLOAD_OPS) covers all its members
    host_covered: Set[int] = set()
    for name in sorted(dispatched):
        val = getattr(pk, name, None)
        if isinstance(val, int):
            host_covered.add(val)
            if val not in declared:
                out.append(Violation(
                    "opcode-coverage", relpath(rdma_path), line,
                    f"on_packets dispatches `pk.{name}` (0x{val:02X}) "
                    "which core/packet.py does not declare in "
                    "OPCODE_NAMES"))
        elif isinstance(val, (tuple, list, set, frozenset)):
            host_covered.update(v for v in val if isinstance(v, int))
        else:
            out.append(Violation(
                "opcode-coverage", relpath(rdma_path), line,
                f"on_packets dispatches `pk.{name}` which "
                "core/packet.py does not define"))

    # engines consume the payload stream on_packets forwards to them
    engine_covered = set(pk.PAYLOAD_OPS)
    for opcode, name in sorted(declared.items()):
        if opcode not in engine_covered and opcode not in host_covered:
            out.append(Violation(
                "opcode-coverage", relpath(CORE / "packet.py"), 0,
                f"opcode {name} (0x{opcode:02X}) has no handler: not in "
                "PAYLOAD_OPS (RX engines) and not dispatched in "
                "rdma.on_packets"))
    return out


# --------------------------------------------------------------------------
# event kinds
# --------------------------------------------------------------------------

def _emit_sites(tree: ast.AST, path: Path) -> List[Tuple[str, int]]:
    """(kind, line) for every recorder emit in one module:
    ``<recorder>.record(tick, "<kind>", ...)``, ``<self>._rec("<kind>",
    ...)`` and the netsim queue hooks ``on_event("<kind>", ...)`` (which
    forward into ``record``)."""
    sites: List[Tuple[str, int]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
        elif isinstance(node.func, ast.Name):
            attr = node.func.id
        else:
            continue
        pos = (1 if attr == "record"
               else 0 if attr in ("_rec", "on_event") else None)
        if pos is None or len(node.args) <= pos:
            continue
        arg = node.args[pos]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            sites.append((arg.value, node.lineno))
    return sites


def check_event_kinds() -> List[Violation]:
    from repro.core import telemetry as tm
    out: List[Violation] = []
    registered = set(tm.EVENT_KINDS)
    emitted: Set[str] = set()
    src_root = REPO_ROOT / "src" / "repro"
    for path in sorted(src_root.rglob("*.py")):
        if "__pycache__" in path.parts or path.name == "telemetry.py":
            continue
        for kind, line in _emit_sites(_parse(path), path):
            emitted.add(kind)
            if kind not in registered:
                out.append(Violation(
                    "event-kinds", relpath(path), line,
                    f"emit site uses kind `{kind}` not registered in "
                    "telemetry.EVENT_KINDS"))
    for kind in sorted(registered - emitted):
        out.append(Violation(
            "event-kinds", relpath(CORE / "telemetry.py"), 0,
            f"EVENT_KINDS registers `{kind}` but no emit site in "
            "src/repro uses it"))
    return out


# --------------------------------------------------------------------------
# counter reconciliation
# --------------------------------------------------------------------------

def check_counters() -> List[Violation]:
    from repro.core import pipeline as pipe
    from repro.core import rdma
    out: List[Violation] = []
    cols = set(pipe.COUNTER_FIELDS)
    harvest = set(rdma.ENGINE_COUNTERS)
    stats = {f.name for f in dataclasses.fields(rdma.NodeStats)}
    pipe_path = relpath(CORE / "pipeline.py")
    rdma_path = relpath(CORE / "rdma.py")

    for col in sorted(cols - harvest):
        out.append(Violation(
            "counter-reconcile", pipe_path, 0,
            f"engine counter column `{col}` rides the carried state but "
            "rdma.ENGINE_COUNTERS never harvests it"))
    for col in sorted(harvest - cols):
        out.append(Violation(
            "counter-reconcile", rdma_path, 0,
            f"ENGINE_COUNTERS harvests `{col}` but "
            "pipeline.COUNTER_FIELDS does not carry that column"))
    for col, host in sorted(rdma.ENGINE_COUNTERS.items()):
        if host not in stats:
            out.append(Violation(
                "counter-reconcile", rdma_path, 0,
                f"ENGINE_COUNTERS maps `{col}` -> NodeStats.{host}, "
                "which is not a NodeStats field"))
    missing = set(pipe.COUNTER_FIELDS) - set(pipe._STATE_FIELDS)
    for col in sorted(missing):
        out.append(Violation(
            "counter-reconcile", pipe_path, 0,
            f"COUNTER_FIELDS lists `{col}` but _STATE_FIELDS does not "
            "carry it through the FSM"))
    return out


def run() -> List[Violation]:
    return check_opcodes() + check_event_kinds() + check_counters()
