"""balint — the BALBOA data-plane invariant checker.

Three pass families (see docs/BALINT.md for the rules table):

* trace purity (jaxpr): host callbacks, f64 promotion, missing buffer
  donation, concretization in every jitted data-plane entry point;
* determinism (AST): wall clock, unseeded RNG, set iteration, unsorted
  dict iteration on wire paths, mutable default args;
* protocol exhaustiveness: opcode coverage, event-kind registration,
  engine-counter reconciliation.

Run it::

    PYTHONPATH=src python -m repro.analysis --strict

or from code::

    from repro.analysis import run_analysis
    report = run_analysis()
    assert report.strict_ok
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Optional

from repro.analysis.report import Report, render_json, render_text
from repro.analysis.violations import (DEFAULT_BASELINE, REPO_ROOT, RULES,
                                       RULE_FAMILIES, Baseline, Violation,
                                       apply_suppressions)

PASS_FAMILIES = ("determinism", "purity", "protocol")
DEFAULT_PATHS = ("src/repro",)


def run_analysis(paths: Optional[Iterable] = None,
                 passes: Optional[Iterable[str]] = None,
                 baseline_path: Optional[Path] = DEFAULT_BASELINE,
                 ) -> Report:
    """Run the selected pass families and reconcile with the baseline.

    ``paths`` scopes the AST determinism pass only — the jaxpr and
    protocol passes address the repo's registered entry points and
    cannot be pointed at fixtures."""
    passes = list(passes) if passes is not None else list(PASS_FAMILIES)
    violations: List[Violation] = []
    if "determinism" in passes:
        from repro.analysis import determinism
        violations += determinism.run(paths or DEFAULT_PATHS)
    if "purity" in passes:
        from repro.analysis import purity
        violations += purity.run()
    if "protocol" in passes:
        from repro.analysis import protocol
        violations += protocol.run()
    violations = apply_suppressions(violations)
    if baseline_path is not None:
        baseline = Baseline.load(baseline_path)
    else:
        baseline = Baseline([])
    # a partial run must not expire entries its passes could never
    # re-produce (e.g. --passes determinism leaving purity debt alone)
    covered = set().union(*(RULE_FAMILIES.get(p, set()) for p in passes))
    baseline = Baseline([e for e in baseline.entries
                         if e.get("rule") in covered])
    active, baselined, expired = baseline.partition(violations)
    return Report(violations=active, baselined=baselined, expired=expired,
                  rules_run=passes)


__all__ = ["run_analysis", "Report", "Baseline", "Violation", "RULES",
           "REPO_ROOT", "render_text", "render_json"]
