"""CLI: ``python -m repro.analysis`` (balint).

Exit status: 0 unless ``--strict`` and the run is not clean (new
violations, or expired baseline entries that must be pruned).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import (DEFAULT_BASELINE, PASS_FAMILIES, Baseline,
                            render_json, render_text, run_analysis)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="balint: jaxpr/AST invariant checker for the BALBOA "
                    "data plane")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined violation or any "
                         "expired baseline entry")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/dirs for the AST determinism pass "
                         "(default: src/repro)")
    ap.add_argument("--passes", nargs="*", choices=PASS_FAMILIES,
                    default=None,
                    help="run only these pass families")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline ledger (default: balint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline ledger entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to absorb every current "
                         "violation (then hand-edit the reasons)")
    ap.add_argument("--census", metavar="OUT.json", default=None,
                    help="run the host-sync census (one epoch per fig "
                         "bench) and write BENCH_sync_census.json-shaped "
                         "output; skips the lint passes")
    args = ap.parse_args(argv)

    if args.census:
        from repro.analysis.census import run_census
        doc = run_census()
        with open(args.census, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        for fig, c in doc["census"].items():
            print(f"{fig}: {c['ticks']} ticks, "
                  f"{c['d2h_per_tick']} d2h/tick, "
                  f"{c['h2d_per_tick']} h2d/tick")
        return 0

    baseline_path = None if args.no_baseline else args.baseline
    report = run_analysis(paths=args.paths, passes=args.passes,
                          baseline_path=baseline_path)

    if args.write_baseline:
        merged = Baseline.load(args.baseline) if not args.no_baseline \
            else Baseline([])
        keep = {(e["rule"], e["path"], e["message"]): e
                for e in merged.entries}
        # drop expired, absorb new
        for e in report.expired:
            keep.pop((e["rule"], e["path"], e["message"]), None)
        for v in report.violations:
            keep.setdefault(v.fingerprint(),
                            {"rule": v.rule, "path": v.path,
                             "message": v.message,
                             "reason": "TODO: justify or fix"})
        Baseline(list(keep.values())).write(args.baseline)
        print(f"wrote {args.baseline} ({len(keep)} entries)")
        return 0

    print(render_json(report) if args.json else render_text(report))
    if args.strict and not report.strict_ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
