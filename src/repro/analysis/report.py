"""Reporters: render an analysis run for humans (text) or tools (JSON)."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

from repro.analysis.violations import Violation


@dataclasses.dataclass
class Report:
    violations: List[Violation]          # active (not baselined)
    baselined: List[Violation]           # matched a baseline entry
    expired: List[dict]                  # baseline entries with no match
    rules_run: List[str]

    @property
    def strict_ok(self) -> bool:
        """--strict contract: no new violations AND no stale baseline
        entries (paid-down debt must be pruned from the ledger)."""
        return not self.violations and not self.expired

    def to_dict(self) -> Dict:
        return {
            "strict_ok": self.strict_ok,
            "rules_run": self.rules_run,
            "violations": [v.to_dict() for v in self.violations],
            "baselined": [v.to_dict() for v in self.baselined],
            "expired_baseline_entries": self.expired,
        }


def render_json(report: Report) -> str:
    return json.dumps(report.to_dict(), indent=2)


def render_text(report: Report) -> str:
    lines: List[str] = []
    if report.violations:
        lines.append(f"{len(report.violations)} violation(s):")
        for v in sorted(report.violations,
                        key=lambda v: (v.path, v.line, v.rule)):
            lines.append(f"  {v.path}:{v.line}: [{v.rule}] {v.message}")
    else:
        lines.append("no violations")
    if report.baselined:
        lines.append(f"{len(report.baselined)} baselined (deliberate, "
                     "see balint_baseline.json):")
        for v in sorted(report.baselined,
                        key=lambda v: (v.path, v.line, v.rule)):
            lines.append(f"  {v.path}:{v.line}: [{v.rule}] {v.message}")
    if report.expired:
        lines.append(f"{len(report.expired)} EXPIRED baseline entr"
                     f"{'y' if len(report.expired) == 1 else 'ies'} "
                     "(violation gone — prune the ledger):")
        for e in report.expired:
            lines.append(f"  [{e['rule']}] {e['path']}: {e['message']}")
    lines.append(f"strict: {'ok' if report.strict_ok else 'FAIL'}")
    return "\n".join(lines)
