"""Trace-purity pass: walk the closed jaxprs of every jitted data-plane
entry point and flag anything that would drag the device graph back to
the host or silently widen it.

Per entry point (RX/TX pipelines in both engines and both rx_modes,
every public kernel wrapper, the fused service chain, the collectives
fold) the pass traces with small representative arguments and checks:

* ``host-callback``  — ``pure_callback``/``io_callback``/
  ``debug_callback`` primitives anywhere in the (recursively nested)
  jaxpr: a host round-trip per invocation;
* ``f64-promotion``  — any float64 intermediate (the data plane is
  int32/float32; an f64 doubles bandwidth and diverges across
  backends);
* ``missing-donation`` — state-carrying entry points (the four
  pipeline engines, whose first argument is the carried table state)
  that do not donate their input buffers: each call copies the whole
  table set (ROADMAP item 2's fused core needs donation to be
  alloc-free per epoch);
* ``concretization`` — tracing itself raises a concretization error
  (a data-dependent Python branch snuck into the graph).

The registry below IS the inventory of jitted entry points; adding a
data-plane entry without registering it here is what code review is
for.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.violations import Violation, relpath

CALLBACK_PRIMITIVES = {"pure_callback", "io_callback", "debug_callback",
                       "callback"}


@dataclasses.dataclass
class EntryPoint:
    """One jitted data-plane entry: ``fn(*args())`` must trace."""
    name: str
    fn: Callable
    args: Callable[[], Tuple[tuple, dict]]
    carries_state: bool = False    # first arg is carried state -> must donate
    site: Optional[Callable] = None   # def site to report (when fn wraps)


def _def_site(fn: Callable) -> Tuple[str, int]:
    target = inspect.unwrap(fn)
    target = getattr(target, "__wrapped__", target)
    try:
        path = inspect.getsourcefile(target) or "<unknown>"
        _, line = inspect.getsourcelines(target)
    except (OSError, TypeError):
        return "<unknown>", 0
    return relpath(path), line


# --------------------------------------------------------------------------
# entry-point registry (small, fixed-seed example arguments)
# --------------------------------------------------------------------------

def _rx_args(sr: int):
    def build():
        import jax.numpy as jnp
        from repro.core import packet as pk
        from repro.core import pipeline as pipe
        tables = pipe.make_rx_tables(4)
        if sr:
            tables = tables._replace(sr=jnp.ones(4, jnp.int32))
        pkts = [pk.Packet(opcode=pk.WRITE_ONLY, qpn=q, psn=0, dma_len=64,
                          payload=np.zeros(64, np.uint8), ack_req=True)
                for q in range(4)]
        batch_np = pk.batch_from_packets(pkts)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                 if k != "payload"}
        return (tables, batch), {}
    return build


def _tx_args():
    import jax.numpy as jnp
    from repro.core import pipeline as pipe
    tables = pipe.make_tx_tables(4)
    cmds = {"qpn": jnp.asarray([0, 1, 2, 3], jnp.int32),
            "n_pkts": jnp.asarray([2, 1, 3, 1], jnp.int32)}
    return (tables, cmds), {}


def _payload(n=4, mtu=4096):
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    return jnp.asarray(rng.integers(0, 256, (n, mtu), dtype=np.uint8))


def _round_keys():
    from repro.kernels.ref import expand_key
    rng = np.random.default_rng(5)
    return expand_key(rng.integers(0, 256, 16, dtype=np.uint8))


def _dpi_params():
    from repro.kernels.dpi_mlp import init_dpi_params, ternarize
    return ternarize(init_dpi_params(jax.random.key(7)))


def registry() -> List[EntryPoint]:
    import jax.numpy as jnp
    from repro.core import pipeline as pipe
    from repro.kernels import fused_chain, ops, reduce as red

    def aes_args():
        import jax.numpy as jnp
        rng = np.random.default_rng(3)
        blocks = jnp.asarray(rng.integers(0, 256, (8, 16), dtype=np.uint8))
        return (blocks, _round_keys()), {}

    def crc_args():
        pay = _payload()
        plen = jnp.asarray([64, 128, 4096, 1], jnp.int32)
        return (pay, plen), {}

    def dpi_args():
        return (_payload(), _dpi_params()), {}

    def preproc_args():
        rng = np.random.default_rng(9)
        recs = jnp.asarray(rng.integers(0, 1 << 20, (16, 39),
                                        dtype=np.int32))
        return (recs,), {}

    def fused_args():
        return (_payload(), _round_keys(), _dpi_params()), {}

    def fold_args():
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.standard_normal((4, 512)).astype(np.float32))
        return (x,), {}

    def chunk_args():
        rng = np.random.default_rng(12)
        pay = jnp.asarray(rng.integers(0, 256, (4, 512), dtype=np.uint8))
        return (pay,), {}

    eps = [
        EntryPoint("rx_pipeline[gbn]", pipe.rx_pipeline, _rx_args(0),
                   carries_state=True),
        EntryPoint("rx_pipeline[sr]", pipe.rx_pipeline, _rx_args(1),
                   carries_state=True),
        EntryPoint("rx_pipeline_batched[gbn]", pipe.rx_pipeline_batched,
                   _rx_args(0), carries_state=True),
        EntryPoint("rx_pipeline_batched[sr]", pipe.rx_pipeline_batched,
                   _rx_args(1), carries_state=True),
        EntryPoint("tx_pipeline", pipe.tx_pipeline, lambda: _tx_args(),
                   carries_state=True),
        EntryPoint("tx_pipeline_batched", pipe.tx_pipeline_batched,
                   lambda: _tx_args(), carries_state=True),
        EntryPoint("kernels.aes_ecb[pallas]",
                   lambda b, rk: ops.aes_ecb(b, rk, impl="pallas"),
                   aes_args, site=ops.aes_ecb),
        EntryPoint("kernels.crc32[pallas]",
                   lambda p, n: ops.crc32(p, n, impl="pallas"), crc_args,
                   site=ops.crc32),
        EntryPoint("kernels.dpi_scores[pallas]",
                   lambda p, w: ops.dpi_scores(p, w, impl="pallas"),
                   dpi_args, site=ops.dpi_scores),
        # n_dense/modulus/tile_recs are Python-static config (callers
        # close over them) — trace them closed so only arrays are traced
        EntryPoint("kernels.preproc[pallas]",
                   lambda r: ops.preproc(r, 13, 100_000, impl="pallas"),
                   preproc_args, site=ops.preproc),
        EntryPoint("kernels.preproc_tile",
                   lambda r: ops.preproc_tile(r, 13, 100_000,
                                              tile_recs=32),
                   preproc_args, site=ops.preproc_tile),
        EntryPoint("kernels.chunk_reduce[pallas]",
                   lambda p: ops.chunk_reduce(p, impl="pallas"),
                   chunk_args, site=ops.chunk_reduce),
        EntryPoint("kernels.fused_decrypt_dpi_pallas",
                   fused_chain.fused_decrypt_dpi_pallas, fused_args),
        EntryPoint("kernels.fused_decrypt_dpi_tile",
                   fused_chain.fused_decrypt_dpi_tile, fused_args),
        EntryPoint("kernels.reduce_fold_ref", red.reduce_fold_ref,
                   fold_args),
        EntryPoint("kernels.reduce_fold_pallas", red.reduce_fold_pallas,
                   fold_args),
    ]
    eps.append(_fused_epoch_entry())
    return eps


def _fused_epoch_entry() -> EntryPoint:
    """The fused epoch core (ROADMAP item 2): pack a minimal star world
    and register its jitted blob->blob epoch function.  carries_state
    pins the donated-carry contract — the whole point of the fused core
    is ONE donated input buffer per epoch, so losing the donation
    annotation is a regression balint must catch."""
    import jax.numpy as jnp
    from repro.core import fused as fz
    from repro.core import netsim
    from repro.core.rdma import RdmaNode

    cfg = netsim.FabricConfig(port_bandwidth=2, port_delay=2,
                              queue_capacity=16, seed=3)
    fab = netsim.SwitchedFabric(2, cfg)
    recv = RdmaNode(0, fab, n_qps=8)
    snd = RdmaNode(1, fab, n_qps=8)
    qpn, _, _ = snd.init_rdma(4096, recv)
    snd.rdma_write(qpn, np.zeros(1024, np.uint8))
    world = fz.try_pack([recv, snd], 64, 8, None)
    assert world is not None, "canonical star world must be fusable"
    return EntryPoint("fused.epoch[star-gbn]",
                      fz.make_epoch_fn(world.skey),
                      lambda: ((jnp.asarray(world.vec0),), {}),
                      carries_state=True, site=fz.make_epoch_fn)


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and in every nested sub-jaxpr
    (pjit / scan / while / cond / pallas_call bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in jax.core.jaxprs_in_params(eqn.params):
            yield from iter_eqns(sub)


def _avals(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


def check_entry(ep: EntryPoint) -> List[Violation]:
    path, line = _def_site(ep.site or ep.fn)
    out: List[Violation] = []
    try:
        args, kwargs = ep.args()
        closed = jax.make_jaxpr(ep.fn)(*args, **kwargs)
    except Exception as e:      # noqa: BLE001 — tracing failures are findings
        kind = type(e).__name__
        if "Concretization" in kind or "TracerBool" in kind \
                or "TracerInteger" in kind:
            out.append(Violation(
                "concretization", path, line,
                f"entry `{ep.name}` fails to trace: {kind}"))
        else:
            out.append(Violation(
                "concretization", path, line,
                f"entry `{ep.name}` raised {kind} during tracing"))
        return out

    callbacks = sorted({e.primitive.name for e in iter_eqns(closed.jaxpr)
                        if e.primitive.name in CALLBACK_PRIMITIVES})
    if callbacks:
        out.append(Violation(
            "host-callback", path, line,
            f"entry `{ep.name}` embeds host callback(s) "
            f"{callbacks} — one device->host round-trip per call"))

    f64 = sorted({e.primitive.name for e in iter_eqns(closed.jaxpr)
                  if any(str(a.dtype) == "float64" for a in _avals(e))})
    if f64:
        out.append(Violation(
            "f64-promotion", path, line,
            f"entry `{ep.name}` carries float64 through {f64}"))

    if ep.carries_state and not _donates(ep, args, kwargs):
        out.append(Violation(
            "missing-donation", path, line,
            f"entry `{ep.name}` does not donate its carried table "
            "state — every call reallocates the full table set"))
    return out


def _donates(ep: EntryPoint, args, kwargs) -> bool:
    """True when the jitted entry point donates at least one input
    buffer (CPU ignores donation at run time but the lowering still
    records donor annotations, so this works on every backend)."""
    lower = getattr(ep.fn, "lower", None)
    if lower is None:
        return False
    try:
        text = lower(*args, **kwargs).as_text()
    except Exception:           # noqa: BLE001
        return False
    return "jax.buffer_donor" in text or "tf.aliasing_output" in text


def run(names: Optional[List[str]] = None) -> List[Violation]:
    out: List[Violation] = []
    for ep in registry():
        if names is not None and ep.name not in names:
            continue
        out.extend(check_entry(ep))
    return out
