"""Shared violation model for balint (the BALBOA invariant checker).

Three concepts every pass speaks:

* ``Violation`` — one finding, fingerprinted by ``(rule, path, message)``
  so baselines survive unrelated line churn;
* suppressions — ``# balint: disable=<rule>[,<rule>...]`` comments, at
  line granularity when trailing code and at file granularity when the
  comment stands alone;
* ``Baseline`` — the committed ledger of *deliberate* violations
  (``balint_baseline.json``).  A baselined violation is reported but
  does not fail ``--strict``; a baseline entry that no longer matches
  anything is *expired* and DOES fail ``--strict``, so the ledger can
  only shrink as debt is paid down.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

REPO_ROOT = Path(__file__).resolve().parents[3]

# rule id -> one-line contract (docs/BALINT.md renders this table)
RULES: Dict[str, str] = {
    # determinism (AST) pass
    "wall-clock": "no wall-clock reads (time.time/perf_counter/"
                  "monotonic, argless datetime.now) in the data plane",
    "unseeded-rng": "no global numpy RNG (np.random.*) and no unseeded "
                    "default_rng() — every stream is seeded",
    "set-iteration": "no iteration over sets — Python set order is "
                     "hash-randomized across runs",
    "dict-order": "no unsorted dict iteration on paths that put packets "
                  "on the wire or emit telemetry events",
    "mutable-default": "no mutable default arguments (list/dict/set)",
    # trace-purity (jaxpr) pass
    "host-callback": "jitted data-plane entry points embed no host "
                     "callbacks (pure/io/debug_callback)",
    "f64-promotion": "no float64 values inside jitted entry points",
    "missing-donation": "state-carrying jitted entry points donate "
                        "their table buffers",
    "concretization": "entry points trace without concretizing "
                      "(no TracerBoolConversion / ConcretizationTypeError)",
    # protocol-exhaustiveness pass
    "opcode-coverage": "every opcode in core/packet.py has a handler in "
                       "the RX engines or the host rdma.py dispatch",
    "event-kinds": "every FlightRecorder emit site uses a kind in "
                   "EVENT_KINDS, and every registered kind is emitted",
    "counter-reconcile": "pipeline.COUNTER_FIELDS, rdma.ENGINE_COUNTERS "
                         "and NodeStats reconcile by name",
}


# which pass family owns each rule — a baseline entry only expires when
# the family that could re-produce it actually ran
RULE_FAMILIES: Dict[str, Set[str]] = {
    "determinism": {"wall-clock", "unseeded-rng", "set-iteration",
                    "dict-order", "mutable-default", "determinism-parse"},
    "purity": {"host-callback", "f64-promotion", "missing-donation",
               "concretization"},
    "protocol": {"opcode-coverage", "event-kinds", "counter-reconcile"},
}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str              # repo-relative, '/'-separated
    line: int              # 1-based; 0 when the finding is file-global
    message: str

    def fingerprint(self) -> Tuple[str, str, str]:
        """Line numbers churn; (rule, path, message) identifies the
        finding across edits elsewhere in the file."""
        return (self.rule, self.path, self.message)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def relpath(p: Path) -> str:
    p = Path(p).resolve()
    try:
        return p.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return p.as_posix()


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------

_DISABLE = re.compile(r"#\s*balint:\s*disable=([\w,\- ]+)")


class Suppressions:
    """Per-file suppression map parsed from ``# balint: disable=`` comments.

    A standalone comment line suppresses the named rules for the whole
    file; a trailing comment suppresses them for that line only."""

    def __init__(self, source: str):
        self.file_rules: Set[str] = set()
        self.line_rules: Dict[int, Set[str]] = {}
        for lineno, text in enumerate(source.splitlines(), 1):
            m = _DISABLE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            if text.strip().startswith("#"):
                self.file_rules |= rules
            else:
                self.line_rules.setdefault(lineno, set()).update(rules)

    def hides(self, v: Violation) -> bool:
        if v.rule in self.file_rules or "all" in self.file_rules:
            return True
        at = self.line_rules.get(v.line, ())
        return v.rule in at or "all" in at


_SUPPRESSION_CACHE: Dict[str, Suppressions] = {}


def suppressions_for(path: str) -> Suppressions:
    """Load (and cache) the suppression map for a repo-relative path."""
    if path not in _SUPPRESSION_CACHE:
        f = REPO_ROOT / path
        try:
            src = f.read_text()
        except OSError:
            src = ""
        _SUPPRESSION_CACHE[path] = Suppressions(src)
    return _SUPPRESSION_CACHE[path]


def apply_suppressions(violations: Iterable[Violation]) -> List[Violation]:
    return [v for v in violations if not suppressions_for(v.path).hides(v)]


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------

DEFAULT_BASELINE = REPO_ROOT / "balint_baseline.json"


class Baseline:
    """Committed ledger of deliberate violations.

    Each entry is ``{"rule", "path", "message", "reason"}``; ``reason``
    is for humans (why this debt is deliberate, what retires it)."""

    def __init__(self, entries: Optional[List[dict]] = None):
        self.entries: List[dict] = entries or []

    @classmethod
    def load(cls, path: Path = DEFAULT_BASELINE) -> "Baseline":
        if not Path(path).exists():
            return cls([])
        with open(path) as f:
            doc = json.load(f)
        return cls(doc.get("entries", []))

    def write(self, path: Path = DEFAULT_BASELINE) -> None:
        doc = {"comment": "deliberate balint debt — see docs/BALINT.md; "
                          "entries expire (and fail --strict) once the "
                          "underlying violation is gone",
               "entries": self.entries}
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")

    def _key(self, e: dict) -> Tuple[str, str, str]:
        return (e["rule"], e["path"], e["message"])

    def partition(self, violations: List[Violation]
                  ) -> Tuple[List[Violation], List[Violation], List[dict]]:
        """Split into (active, baselined, expired-baseline-entries)."""
        keys = {self._key(e): e for e in self.entries}
        active, baselined, matched = [], [], set()
        for v in violations:
            if v.fingerprint() in keys:
                baselined.append(v)
                matched.add(v.fingerprint())
            else:
                active.append(v)
        expired = [e for e in self.entries if self._key(e) not in matched]
        return active, baselined, expired

    @classmethod
    def from_violations(cls, violations: List[Violation],
                        reason: str = "TODO: justify or fix") -> "Baseline":
        entries = [{"rule": v.rule, "path": v.path, "message": v.message,
                    "reason": reason} for v in violations]
        return cls(entries)
